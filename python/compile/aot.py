"""AOT bridge: lower the L2 functions to HLO **text** + write weights and
the manifest the Rust runtime consumes.

Run once via ``make artifacts`` (no-op when inputs are unchanged); Python
never runs on the request path.

HLO text — not ``.serialize()`` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def build_weights(cfg: M.TinyConfig, seed: int = 0):
    """Random-initialized weights, scaled for stable propagation."""
    rng = np.random.RandomState(seed)
    h, f = cfg.hidden, cfg.intermediate
    qh, kvh, d = cfg.q_heads, cfg.kv_heads, cfg.head_dim

    def mat(*shape, scale=None):
        scale = scale if scale is not None else 1.0 / np.sqrt(shape[0])
        return (rng.randn(*shape) * scale).astype(np.float32)

    weights = {"emb": mat(cfg.vocab, h, scale=0.5), "final_norm": np.ones(h, np.float32)}
    for l in range(cfg.layers):
        p = f"l{l}."
        weights[p + "attn_norm"] = np.ones(h, np.float32)
        weights[p + "wq"] = mat(h, qh * d)
        weights[p + "wk"] = mat(h, kvh * d)
        weights[p + "wv"] = mat(h, kvh * d)
        weights[p + "wo"] = mat(qh * d, h)
        weights[p + "ffn_norm"] = np.ones(h, np.float32)
        weights[p + "wg"] = mat(h, cfg.experts)
        for e in range(cfg.experts):
            ep = f"{p}e{e}."
            weights[ep + "w1"] = mat(h, f)
            weights[ep + "w3"] = mat(h, f)
            weights[ep + "w2"] = mat(f, h)
    return weights


def lower_all(cfg: M.TinyConfig):
    """Lower each disaggregated function at the fixed micro-batch size."""
    b, h, s = cfg.micro_batch, cfg.hidden, cfg.max_seq
    kvh, d, qh = cfg.kv_heads, cfg.head_dim, cfg.q_heads
    f32 = jnp.float32
    i32 = jnp.int32
    spec = jax.ShapeDtypeStruct

    shapes = {
        "attention": (
            spec((b, h), f32),
            spec((b, s, kvh, d), f32),
            spec((b, s, kvh, d), f32),
            spec((b,), i32),
            spec((h,), f32),
            spec((h, qh * d), f32),
            spec((h, kvh * d), f32),
            spec((h, kvh * d), f32),
            spec((qh * d, h), f32),
        ),
        "gating": (
            spec((b, h), f32),
            spec((h,), f32),
            spec((h, cfg.experts), f32),
        ),
        "expert": (
            spec((b, h), f32),
            spec((h, cfg.intermediate), f32),
            spec((h, cfg.intermediate), f32),
            spec((cfg.intermediate, h), f32),
        ),
        "experts_grouped": (
            spec((cfg.experts, b, h), f32),
            spec((cfg.experts, h, cfg.intermediate), f32),
            spec((cfg.experts, h, cfg.intermediate), f32),
            spec((cfg.experts, cfg.intermediate, h), f32),
        ),
        "embed": (spec((b,), i32), spec((cfg.vocab, h), f32)),
        "lm_head": (spec((b, h), f32), spec((h,), f32), spec((cfg.vocab, h), f32)),
    }
    fns = {
        "attention": M.attention_step_tuple,
        "gating": M.gating_tuple,
        "expert": M.expert_fn,
        "experts_grouped": M.experts_grouped_fn,
        "embed": M.embed_fn,
        "lm_head": M.lm_head_fn,
    }
    return {
        name: to_hlo_text(jax.jit(fns[name]).lower(*shapes[name])) for name in fns
    }


def build_test_vectors(cfg: M.TinyConfig, weights, seed: int = 1):
    """Golden input/output pairs, computed by JAX, checked by Rust."""
    rng = np.random.RandomState(seed)
    b, h, s = cfg.micro_batch, cfg.hidden, cfg.max_seq
    kvh, d = cfg.kv_heads, cfg.head_dim

    def arr(name, a):
        a = np.asarray(a, np.float32)
        # Shortest-repr rounding keeps the manifest small; the Rust check
        # uses atol=1e-3 so 7 significant digits are ample.
        return {
            "name": name,
            "shape": list(a.shape),
            "data": [float(f"{x:.7g}") for x in a.ravel()],
        }

    def wref(name, weight_name):
        """Reference a tensor already present in weights.bin by name."""
        return {"name": name, "weight": weight_name}

    vectors = []

    # expert
    x = rng.randn(b, h).astype(np.float32) * 0.3
    w = weights["l0.e0.w1"], weights["l0.e0.w3"], weights["l0.e0.w2"]
    (y,) = M.expert_fn(jnp.asarray(x), *map(jnp.asarray, w))
    vectors.append(
        {
            "name": "expert",
            "inputs": [arr("x", x), wref("w1", "l0.e0.w1"), wref("w3", "l0.e0.w3"), wref("w2", "l0.e0.w2")],
            "outputs": [arr("y", np.asarray(y))],
        }
    )

    # gating
    gamma, wg = weights["l0.ffn_norm"], weights["l0.wg"]
    normed, logits = M.gating_fn(jnp.asarray(x), jnp.asarray(gamma), jnp.asarray(wg))
    vectors.append(
        {
            "name": "gating",
            "inputs": [arr("x", x), wref("gamma", "l0.ffn_norm"), wref("wg", "l0.wg")],
            "outputs": [arr("normed", np.asarray(normed)), arr("logits", np.asarray(logits))],
        }
    )

    # attention (positions staggered across slots; caches pre-filled)
    k_cache = (rng.randn(b, s, kvh, d) * 0.1).astype(np.float32)
    v_cache = (rng.randn(b, s, kvh, d) * 0.1).astype(np.float32)
    positions = (np.arange(b) % (s // 2)).astype(np.int32)
    aw = [weights[f"l0.{n}"] for n in ("attn_norm", "wq", "wk", "wv", "wo")]
    h1, nk, nv = M.attention_step(
        jnp.asarray(x),
        jnp.asarray(k_cache),
        jnp.asarray(v_cache),
        jnp.asarray(positions),
        *map(jnp.asarray, aw),
    )
    vectors.append(
        {
            "name": "attention",
            "inputs": [
                arr("x", x),
                arr("k_cache", k_cache),
                arr("v_cache", v_cache),
                {
                    "name": "positions",
                    "shape": [b],
                    "data": [float(p) for p in positions],
                },
            ]
            + [wref(n, f"l0.{n}") for n in ("attn_norm", "wq", "wk", "wv", "wo")],
            "outputs": [
                arr("h1", np.asarray(h1)),
                arr("new_k", np.asarray(nk)),
                arr("new_v", np.asarray(nv)),
            ],
        }
    )

    # embed + lm_head
    ids = rng.randint(0, cfg.vocab, size=b).astype(np.int32)
    (xe,) = M.embed_fn(jnp.asarray(ids), jnp.asarray(weights["emb"]))
    vectors.append(
        {
            "name": "embed",
            "inputs": [
                {"name": "ids", "shape": [b], "data": [float(i) for i in ids]},
                wref("emb", "emb"),
            ],
            "outputs": [arr("x", np.asarray(xe))],
        }
    )
    (logits,) = M.lm_head_fn(
        jnp.asarray(x), jnp.asarray(weights["final_norm"]), jnp.asarray(weights["emb"])
    )
    vectors.append(
        {
            "name": "lm_head",
            "inputs": [arr("x", x), wref("final_norm", "final_norm"), wref("emb", "emb")],
            "outputs": [arr("logits", np.asarray(logits))],
        }
    )
    return vectors


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifacts directory")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    cfg = M.TinyConfig()
    os.makedirs(args.out, exist_ok=True)

    # 1. HLO text per executable.
    executables = {}
    for name, text in lower_all(cfg).items():
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        executables[name] = fname
        print(f"  lowered {name}: {len(text)} chars")

    # 2. Weights blob + tensor table.
    weights = build_weights(cfg, args.seed)
    tensors = []
    offset = 0
    blob = []
    for name in sorted(weights):
        a = weights[name]
        tensors.append({"name": name, "shape": list(a.shape), "offset": offset})
        blob.append(a.ravel())
        offset += a.size
    with open(os.path.join(args.out, "weights.bin"), "wb") as f:
        f.write(np.concatenate(blob).astype("<f4").tobytes())
    print(f"  weights.bin: {offset * 4} bytes, {len(tensors)} tensors")

    # 3. Test vectors (JAX golden outputs for the Rust numerics test).
    vectors = build_test_vectors(cfg, weights)

    manifest = {
        "model": {
            "layers": cfg.layers,
            "hidden": cfg.hidden,
            "intermediate": cfg.intermediate,
            "experts": cfg.experts,
            "top_k": cfg.top_k,
            "q_heads": cfg.q_heads,
            "kv_heads": cfg.kv_heads,
            "head_dim": cfg.head_dim,
            "vocab": cfg.vocab,
            "max_seq": cfg.max_seq,
            "micro_batch": cfg.micro_batch,
        },
        "executables": executables,
        "weights_file": "weights.bin",
        "tensors": tensors,
        "test_vectors": vectors,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    print(f"  manifest.json written to {args.out}")


if __name__ == "__main__":
    main()
