"""L1 Pallas kernel: single-token GQA decode attention.

The memory-intensive core of an attention node (§2.1: every decode step
scans each request's own KV cache, so batching cannot raise arithmetic
intensity — the reason attention nodes are provisioned for bandwidth).

TPU mapping (DESIGN.md §Hardware-Adaptation): grid over the batch; each grid
step streams one request's ``[S, KVH, D]`` K/V panels HBM→VMEM and keeps an
online-softmax accumulator in VMEM. GQA query groups share a single K/V
panel load (the ``bkgd,bskd`` contraction below). Per-step VMEM:
``2·S·KVH·D + QH·D`` elements ≈ 1 MB for the compiled shapes.

NOTE: ``interpret=True`` — see expert_ffn.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(q_ref, k_ref, v_ref, pos_ref, o_ref):
    # Block shapes: q [1, QH, D]; k,v [1, S, KVH, D]; pos [1].
    q = q_ref[0]  # [QH, D]
    k = k_ref[0]  # [S, KVH, D]
    v = v_ref[0]
    pos = pos_ref[0]

    qh, d = q.shape
    s, kvh, _ = k.shape
    g = qh // kvh
    qg = q.reshape(kvh, g, d)

    scores = jnp.einsum("kgd,skd->kgs", qg, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    mask = (jnp.arange(s) <= pos)[None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    m = scores.max(axis=-1, keepdims=True)
    p = jnp.exp(scores - m)
    p = p / p.sum(axis=-1, keepdims=True)
    out = jnp.einsum("kgs,skd->kgd", p, v)
    o_ref[0] = out.reshape(qh, d)


@jax.jit
def attention_core(q, k_cache, v_cache, positions):
    """Masked GQA decode attention as a Pallas kernel.

    q: [b, QH, D]; k_cache, v_cache: [b, S, KVH, D]; positions: [b] int32
    (cache entries 0..pos inclusive are attended). Returns [b, QH, D].
    """
    b, qh, d = q.shape
    s, kvh = k_cache.shape[1], k_cache.shape[2]

    return pl.pallas_call(
        _kernel,
        grid=(b,),
        in_specs=[
            pl.BlockSpec((1, qh, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, kvh, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, s, kvh, d), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((1, qh, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, qh, d), q.dtype),
        interpret=True,
    )(q, k_cache, v_cache, positions)
