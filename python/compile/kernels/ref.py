"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every kernel in this package has a reference implementation here written in
straight-line jax.numpy. ``python/tests/test_kernels.py`` sweeps shapes and
dtypes with hypothesis and asserts allclose between kernel and oracle.
"""

import jax.numpy as jnp


def rmsnorm(x, gamma, eps=1e-6):
    """RMSNorm over the last axis: x / rms(x) * gamma."""
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * (1.0 / jnp.sqrt(ms + eps)) * gamma


def silu(x):
    return x * (1.0 / (1.0 + jnp.exp(-x)))


def expert_ffn(x, w1, w3, w2):
    """SwiGLU expert: (silu(x @ w1) * (x @ w3)) @ w2.

    x: [b, h]; w1, w3: [h, f]; w2: [f, h].
    """
    return (silu(x @ w1) * (x @ w3)) @ w2


def gating(x, gamma, wg):
    """Fused pre-FFN RMSNorm + router logits.

    Returns (normed [b, h], logits [b, E]).
    """
    normed = rmsnorm(x, gamma)
    return normed, normed @ wg


def attention_core(q, k_cache, v_cache, positions):
    """Single-token GQA decode attention against a fixed-capacity KV cache.

    q:         [b, QH, D]   query of the current token
    k_cache:   [b, S, KVH, D]
    v_cache:   [b, S, KVH, D]
    positions: [b] int32    index of the current token in the cache; entries
                            0..pos (inclusive) are valid.
    Returns    [b, QH, D].
    """
    b, qh, d = q.shape
    s = k_cache.shape[1]
    kvh = k_cache.shape[2]
    g = qh // kvh

    qg = q.reshape(b, kvh, g, d)
    # scores[b, kvh, g, s]
    scores = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache) / jnp.sqrt(
        jnp.asarray(d, q.dtype)
    )
    mask = jnp.arange(s)[None, :] <= positions[:, None]  # [b, s]
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    probs = jnp.exp(scores - scores.max(axis=-1, keepdims=True))
    probs = probs / probs.sum(axis=-1, keepdims=True)
    out = jnp.einsum("bkgs,bskd->bkgd", probs, v_cache)
    return out.reshape(b, qh, d)
