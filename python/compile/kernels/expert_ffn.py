"""L1 Pallas kernel: SwiGLU expert FFN.

This is the compute hot-spot of an expert node (paper Table 2: "FFN Input" /
"FFN Output" GEMMs; the real models are gated, so the up-projection shape
occurs twice).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the token axis
in ``block_b`` rows; each grid step streams the full weight panels HBM→VMEM
once and drives the MXU with an ``[block_b, h] x [h, f]`` matmul. VMEM
working set per step is ``block_b·h + 2·h·f + f·h + block_b·f`` elements —
sized well under the ~16 MB VMEM budget for the shapes we compile
(block_b ≤ 128, h ≤ 1024, f ≤ 2048 ⇒ ≤ 13 MB in f32).

NOTE: lowered with ``interpret=True`` — real-TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute (see /opt/xla-example/README).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    x = x_ref[...]
    up = x @ w1_ref[...]
    gate = x @ w3_ref[...]
    act = up * (1.0 / (1.0 + jnp.exp(-up))) * gate  # silu(up) * gate
    o_ref[...] = act @ w2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_b",))
def expert_ffn(x, w1, w3, w2, block_b=None):
    """SwiGLU expert: ``(silu(x @ w1) * (x @ w3)) @ w2`` as a Pallas kernel.

    x: [b, h]; w1, w3: [h, f]; w2: [f, h]. ``block_b`` tiles the token axis
    (defaults to min(b, 128)).
    """
    b, h = x.shape
    f = w1.shape[1]
    if block_b is None:
        block_b = min(b, 128)
    assert b % block_b == 0, f"batch {b} not divisible by block {block_b}"

    return pl.pallas_call(
        _kernel,
        grid=(b // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, h), lambda i: (i, 0)),
            pl.BlockSpec((h, f), lambda i: (0, 0)),
            pl.BlockSpec((h, f), lambda i: (0, 0)),
            pl.BlockSpec((f, h), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)


def _kernel_grouped(x_ref, w1_ref, w3_ref, w2_ref, o_ref):
    # Batched over the experts in this block: [be,b,h] @ [be,h,f].
    x = x_ref[...]
    up = jnp.einsum("ebh,ehf->ebf", x, w1_ref[...])
    gate = jnp.einsum("ebh,ehf->ebf", x, w3_ref[...])
    act = up * (1.0 / (1.0 + jnp.exp(-up))) * gate
    o_ref[...] = jnp.einsum("ebf,efh->ebh", act, w2_ref[...])


@functools.partial(jax.jit, static_argnames=("block_e",))
def expert_ffn_grouped(x, w1, w3, w2, block_e=None):
    """All experts' SwiGLU FFNs in ONE kernel (grouped-GEMM style, §6
    "fused kernels" / §Perf): grid over the expert axis, each step streams
    one expert's weight panels and computes its (padded) token block.

    x: [E, b, h]; w1, w3: [E, h, f]; w2: [E, f, h]. Returns [E, b, h].

    One kernel launch per layer instead of up to E — the launch/dispatch
    amortization MegaScale-Infer's fused kernels target on GPU, realized
    here as a single PJRT executable call on the serving path.

    ``block_e`` experts are processed per grid step. On a real TPU the VMEM
    budget forces block_e=1 (one expert's panels at a time); the tiny
    CPU-demo model fits all experts at once, where block_e=E minimizes the
    interpret-mode grid overhead (§Perf).
    """
    e, b, h = x.shape
    f = w1.shape[2]
    if block_e is None:
        block_e = e if (b * h + 2 * h * f + f * h) * e * 4 < 16 << 20 else 1
    assert e % block_e == 0
    return pl.pallas_call(
        _kernel_grouped,
        grid=(e // block_e,),
        in_specs=[
            pl.BlockSpec((block_e, b, h), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_e, h, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_e, h, f), lambda i: (i, 0, 0)),
            pl.BlockSpec((block_e, f, h), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((block_e, b, h), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((e, b, h), x.dtype),
        interpret=True,
    )(x, w1, w3, w2)
