"""L1 Pallas kernel: fused pre-FFN RMSNorm + router logits.

Paper §6 ("Fused kernels"): attention nodes fuse the gating computation with
the adjacent memory-intensive operators to cut kernel launches and memory
round-trips. Here the pre-FFN RMSNorm and the router GEMM run in one kernel
and emit both the normalized activations (consumed by the experts after
dispatch) and the logits (consumed by the coordinator's top-k).

The top-k selection itself and the scatter are *coordination*, not GPU
compute, in the disaggregated architecture — they live in the Rust L3
(``coordinator::gating`` / ``coordinator::dispatch``).

NOTE: ``interpret=True`` — see expert_ffn.py.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, gamma_ref, wg_ref, normed_ref, logits_ref):
    x = x_ref[...]
    ms = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    normed = x * (1.0 / jnp.sqrt(ms + 1e-6)) * gamma_ref[...]
    normed_ref[...] = normed
    logits_ref[...] = normed @ wg_ref[...]


@jax.jit
def gating(x, gamma, wg):
    """Fused RMSNorm + router logits. x: [b, h]; gamma: [h]; wg: [h, E].

    Returns (normed [b, h], logits [b, E]).
    """
    b, h = x.shape
    e = wg.shape[1]
    return pl.pallas_call(
        _kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((b, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, e), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((b, h), lambda i: (0, 0)),
            pl.BlockSpec((b, e), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, h), x.dtype),
            jax.ShapeDtypeStruct((b, e), x.dtype),
        ],
        interpret=True,
    )(x, gamma, wg)
