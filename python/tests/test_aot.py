"""AOT path: lowering produces parseable HLO text with the agreed entry
signature, and the weight/manifest layout is self-consistent."""

import json

import numpy as np

from compile import aot
from compile import model as M

CFG = M.TinyConfig(layers=1, hidden=32, intermediate=64, experts=4, top_k=2,
                   q_heads=4, kv_heads=2, head_dim=8, vocab=64, max_seq=16,
                   micro_batch=4)


def test_lower_all_produces_hlo_text():
    hlos = aot.lower_all(CFG)
    assert set(hlos) == {"attention", "gating", "expert", "experts_grouped", "embed", "lm_head"}
    for name, text in hlos.items():
        assert text.startswith("HloModule"), f"{name} not HLO text"
        assert "ENTRY" in text, f"{name} missing entry computation"
        # Tuple return (return_tuple=True) so the Rust side can to_tuple().
        assert "tuple" in text or ")->(" in text.replace(" ", ""), name


def test_hlo_parameter_counts_match_contract():
    hlos = aot.lower_all(CFG)
    expected_params = {
        "attention": 9,
        "gating": 3,
        "expert": 4,
        "experts_grouped": 4,
        "embed": 2,
        "lm_head": 3,
    }
    for name, n in expected_params.items():
        lines = hlos[name].splitlines()
        start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
        count = 0
        for line in lines[start:]:
            if " parameter(" in line:
                count += 1
            if line.strip() == "}" and line.startswith("}"):
                break
        assert count == n, (name, count)


def test_weights_cover_all_modules():
    w = aot.build_weights(CFG)
    assert "emb" in w and "final_norm" in w
    for l in range(CFG.layers):
        for part in ("attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "wg"):
            assert f"l{l}.{part}" in w
        for e in range(CFG.experts):
            for part in ("w1", "w3", "w2"):
                assert f"l{l}.e{e}.{part}" in w
    assert w["emb"].shape == (CFG.vocab, CFG.hidden)
    assert w["l0.e0.w1"].shape == (CFG.hidden, CFG.intermediate)


def test_weights_deterministic_by_seed():
    a = aot.build_weights(CFG, seed=3)
    b = aot.build_weights(CFG, seed=3)
    c = aot.build_weights(CFG, seed=4)
    np.testing.assert_array_equal(a["l0.wq"], b["l0.wq"])
    assert not np.array_equal(a["l0.wq"], c["l0.wq"])


def test_test_vectors_json_serializable_and_tagged():
    w = aot.build_weights(CFG)
    vectors = aot.build_test_vectors(CFG, w)
    names = {v["name"] for v in vectors}
    assert names == {"expert", "gating", "attention", "embed", "lm_head"}
    text = json.dumps(vectors)  # must not raise
    back = json.loads(text)
    for v in back:
        for side in ("inputs", "outputs"):
            for na in v[side]:
                assert "weight" in na or ("shape" in na and "data" in na)
