"""L2 correctness: the disaggregated model functions — shapes, KV-cache
scatter semantics, idempotent passive-slot rewrites (the property the Rust
serving loop's prefill relies on), and MoE composition equivalence.
"""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model as M
from compile.kernels import ref

settings.register_profile("model", max_examples=15, deadline=None)
settings.load_profile("model")

CFG = M.TinyConfig(layers=2, hidden=32, intermediate=64, experts=4, top_k=2,
                   q_heads=4, kv_heads=2, head_dim=8, vocab=64, max_seq=16,
                   micro_batch=4)


def weights(seed=0, cfg=CFG):
    rng = np.random.default_rng(seed)
    h, d = cfg.hidden, cfg.head_dim

    def mat(*shape):
        return jnp.asarray((rng.standard_normal(shape) / np.sqrt(shape[0])).astype(np.float32))

    return dict(
        attn_norm=jnp.ones(h),
        wq=mat(h, cfg.q_heads * d),
        wk=mat(h, cfg.kv_heads * d),
        wv=mat(h, cfg.kv_heads * d),
        wo=mat(cfg.q_heads * d, h),
    )


def fresh_state(seed=1, cfg=CFG):
    rng = np.random.default_rng(seed)
    b, s, kvh, d = cfg.micro_batch, cfg.max_seq, cfg.kv_heads, cfg.head_dim
    x = jnp.asarray(rng.standard_normal((b, cfg.hidden)).astype(np.float32) * 0.4)
    k = jnp.asarray(rng.standard_normal((b, s, kvh, d)).astype(np.float32) * 0.1)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, d)).astype(np.float32) * 0.1)
    return x, k, v


def test_attention_step_shapes():
    w = weights()
    x, k, v = fresh_state()
    pos = jnp.zeros(CFG.micro_batch, jnp.int32)
    h1, nk, nv = M.attention_step(x, k, v, pos, **w)
    assert h1.shape == x.shape
    assert nk.shape == k.shape and nv.shape == v.shape


@given(seed=st.integers(0, 1000))
def test_kv_scatter_writes_only_position(seed):
    rng = np.random.default_rng(seed)
    w = weights(seed)
    x, k, v = fresh_state(seed + 1)
    pos = jnp.asarray(rng.integers(0, CFG.max_seq, CFG.micro_batch).astype(np.int32))
    _, nk, nv = M.attention_step(x, k, v, pos, **w)
    nk, nv, k, v = map(np.asarray, (nk, nv, k, v))
    for i, p in enumerate(np.asarray(pos)):
        # Every slot except p is unchanged.
        mask = np.ones(CFG.max_seq, bool)
        mask[p] = False
        np.testing.assert_array_equal(nk[i, mask], k[i, mask])
        np.testing.assert_array_equal(nv[i, mask], v[i, mask])
        # Slot p now holds this token's projected k/v.
        xn = np.asarray(ref.rmsnorm(x, w["attn_norm"]))[i]
        want_k = (xn @ np.asarray(w["wk"])).reshape(CFG.kv_heads, CFG.head_dim)
        np.testing.assert_allclose(nk[i, p], want_k, atol=1e-5)


def test_passive_slot_rewrite_is_idempotent():
    """Re-running the step with the same x and pos leaves KV unchanged —
    the property the Rust prefill relies on for passive slots."""
    w = weights()
    x, k, v = fresh_state()
    pos = jnp.asarray(np.array([1, 2, 3, 4], np.int32))
    h1a, k1, v1 = M.attention_step(x, k, v, pos, **w)
    h1b, k2, v2 = M.attention_step(x, k1, v1, pos, **w)
    np.testing.assert_allclose(np.asarray(k1), np.asarray(k2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(v1), np.asarray(v2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(h1a), np.asarray(h1b), atol=1e-5)


def test_attention_is_causal_in_decode_order():
    """Tokens written later do not change earlier steps' outputs: the step
    at pos=2 only sees entries 0..2 even if 3.. contain garbage."""
    w = weights()
    x, k, v = fresh_state()
    garbage_k = k.at[:, 5:].set(50.0)
    garbage_v = v.at[:, 5:].set(-50.0)
    pos = jnp.asarray(np.full(CFG.micro_batch, 2, np.int32))
    clean, _, _ = M.attention_step(x, k, v, pos, **w)
    dirty, _, _ = M.attention_step(x, garbage_k, garbage_v, pos, **w)
    np.testing.assert_allclose(np.asarray(clean), np.asarray(dirty), atol=1e-5)


def test_moe_composition_matches_dense_equivalent():
    """gating + per-expert FFN + weighted combine == direct computation of
    the same mixture, mirroring what the Rust coordinator assembles."""
    rng = np.random.default_rng(7)
    cfg = CFG
    h, f, E, K = cfg.hidden, cfg.intermediate, cfg.experts, cfg.top_k
    x = jnp.asarray(rng.standard_normal((cfg.micro_batch, h)).astype(np.float32) * 0.4)
    gamma = jnp.ones(h)
    wg = jnp.asarray((rng.standard_normal((h, E)) / np.sqrt(h)).astype(np.float32))
    ew = [
        tuple(
            jnp.asarray((rng.standard_normal(s) / np.sqrt(s[0])).astype(np.float32))
            for s in ((h, f), (h, f), (f, h))
        )
        for _ in range(E)
    ]

    normed, logits = M.gating_fn(x, gamma, wg)
    normed, logits = np.asarray(normed), np.asarray(logits)

    # Top-k combine exactly as the coordinator does it.
    out = np.zeros_like(normed)
    for t in range(normed.shape[0]):
        row = logits[t]
        top = np.argsort(-row)[:K]
        p = np.exp(row[top] - row[top].max())
        p = p / p.sum()
        for e, wgt in zip(top, p):
            y = np.asarray(M.expert_fn(jnp.asarray(normed[t:t + 1]), *ew[e])[0])[0]
            out[t] += wgt * y

    # Dense equivalent in one jnp expression.
    want = np.zeros_like(out)
    sm = np.exp(logits - logits.max(axis=-1, keepdims=True))
    sm = sm / sm.sum(axis=-1, keepdims=True)
    for t in range(normed.shape[0]):
        top = np.argsort(-logits[t])[:K]
        norm = sm[t, top].sum()
        for e in top:
            y = np.asarray(ref.expert_ffn(jnp.asarray(normed[t:t + 1]), *ew[e]))[0]
            want[t] += (sm[t, e] / norm) * y
    np.testing.assert_allclose(out, want, atol=1e-4)


def test_embed_lm_head_roundtrip_prefers_same_token():
    """With tied embeddings and near-orthogonal rows, lm_head(embed(t))
    argmaxes back to t for most tokens — a sanity check on the head."""
    rng = np.random.default_rng(9)
    cfg = CFG
    emb = jnp.asarray((rng.standard_normal((cfg.vocab, cfg.hidden)) * 0.5).astype(np.float32))
    ids = jnp.asarray(np.arange(0, cfg.micro_batch, dtype=np.int32))
    (x,) = M.embed_fn(ids, emb)
    (logits,) = M.lm_head_fn(x, jnp.ones(cfg.hidden), emb)
    pred = np.argmax(np.asarray(logits), axis=-1)
    assert (pred == np.asarray(ids)).mean() >= 0.75
