"""L1 correctness: Pallas kernels vs pure-jnp oracles (ref.py).

Hypothesis sweeps shapes and value scales; assert_allclose against the
reference for every kernel. This is the core correctness signal for the
compute layer — the AOT path lowers exactly these kernels into the HLO the
Rust runtime executes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention as attn_k
from compile.kernels import expert_ffn as expert_k
from compile.kernels import gating as gating_k
from compile.kernels import ref

jax.config.update("jax_enable_x64", False)

settings.register_profile("kernels", max_examples=25, deadline=None)
settings.load_profile("kernels")


def rnd(rng, *shape, scale=1.0):
    return (rng.standard_normal(shape) * scale).astype(np.float32)


# ---------------------------------------------------------------- expert ffn
@given(
    b=st.sampled_from([1, 2, 4, 8, 16]),
    h=st.sampled_from([8, 32, 64, 256]),
    f=st.sampled_from([16, 64, 512]),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_matches_ref(b, h, f, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, b, h, scale=0.5)
    w1, w3 = rnd(rng, h, f, scale=h**-0.5), rnd(rng, h, f, scale=h**-0.5)
    w2 = rnd(rng, f, h, scale=f**-0.5)
    got = expert_k.expert_ffn(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w3), jnp.asarray(w2))
    want = ref.expert_ffn(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w3), jnp.asarray(w2))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("block_b", [1, 2, 4, 8])
def test_expert_ffn_blocking_invariant(block_b):
    """Different token-axis tilings must give identical results."""
    rng = np.random.default_rng(0)
    x, w1, w3, w2 = rnd(rng, 8, 32), rnd(rng, 32, 64), rnd(rng, 32, 64), rnd(rng, 64, 32)
    full = expert_k.expert_ffn(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w3), jnp.asarray(w2), block_b=8)
    tiled = expert_k.expert_ffn(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w3), jnp.asarray(w2), block_b=block_b)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tiled), atol=1e-5)


def test_expert_ffn_zero_input_is_zero():
    z = jnp.zeros((4, 16))
    w = jnp.ones((16, 32)), jnp.ones((16, 32)), jnp.ones((32, 16))
    out = expert_k.expert_ffn(z, *w)
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-7)


# ------------------------------------------------------------------- gating
@given(
    b=st.sampled_from([1, 4, 8, 32]),
    h=st.sampled_from([8, 64, 256]),
    e=st.sampled_from([2, 8, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gating_matches_ref(b, h, e, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, b, h, scale=0.7)
    gamma = rnd(rng, h, scale=1.0) + 1.0
    wg = rnd(rng, h, e, scale=h**-0.5)
    gn, gl = gating_k.gating(jnp.asarray(x), jnp.asarray(gamma), jnp.asarray(wg))
    rn, rl = ref.gating(jnp.asarray(x), jnp.asarray(gamma), jnp.asarray(wg))
    np.testing.assert_allclose(np.asarray(gn), np.asarray(rn), atol=2e-5, rtol=2e-4)
    np.testing.assert_allclose(np.asarray(gl), np.asarray(rl), atol=2e-4, rtol=2e-4)


def test_gating_norm_is_scale_invariant_direction():
    """RMSNorm output has unit RMS (gamma=1): per-row mean square == 1."""
    rng = np.random.default_rng(1)
    x = rnd(rng, 8, 64, scale=3.0)
    gn, _ = gating_k.gating(jnp.asarray(x), jnp.ones(64), jnp.eye(64))
    ms = np.mean(np.square(np.asarray(gn)), axis=-1)
    np.testing.assert_allclose(ms, 1.0, atol=1e-3)


# ---------------------------------------------------------------- attention
@given(
    b=st.sampled_from([1, 2, 8]),
    s=st.sampled_from([4, 16, 64]),
    kvh=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_core_matches_ref(b, s, kvh, g, d, seed):
    rng = np.random.default_rng(seed)
    qh = kvh * g
    q = rnd(rng, b, qh, d, scale=0.5)
    k = rnd(rng, b, s, kvh, d, scale=0.5)
    v = rnd(rng, b, s, kvh, d, scale=0.5)
    pos = rng.integers(0, s, size=b).astype(np.int32)
    got = attn_k.attention_core(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(pos))
    want = ref.attention_core(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(pos))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=3e-4, rtol=3e-4)


def test_attention_mask_excludes_future():
    """Entries beyond pos must not affect the output."""
    rng = np.random.default_rng(2)
    b, s, kvh, d, qh = 2, 8, 1, 4, 2
    q = rnd(rng, b, qh, d)
    k = rnd(rng, b, s, kvh, d)
    v = rnd(rng, b, s, kvh, d)
    pos = np.array([3, 5], dtype=np.int32)
    base = np.asarray(attn_k.attention_core(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(pos)))
    # Corrupt the masked region.
    k2, v2 = k.copy(), v.copy()
    k2[0, 4:] = 99.0
    v2[0, 4:] = -99.0
    k2[1, 6:] = 99.0
    v2[1, 6:] = -99.0
    out = np.asarray(attn_k.attention_core(jnp.asarray(q), jnp.asarray(k2), jnp.asarray(v2), jnp.asarray(pos)))
    np.testing.assert_allclose(out, base, atol=1e-5)


def test_attention_single_valid_token_returns_its_value():
    """pos=0: softmax over one entry -> output == v[0]."""
    rng = np.random.default_rng(3)
    b, s, kvh, d, qh = 1, 4, 1, 4, 2
    q = rnd(rng, b, qh, d)
    k = rnd(rng, b, s, kvh, d)
    v = rnd(rng, b, s, kvh, d)
    pos = np.zeros(b, dtype=np.int32)
    out = np.asarray(attn_k.attention_core(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v), jnp.asarray(pos)))
    want = np.broadcast_to(v[0, 0, 0], (qh, d))
    np.testing.assert_allclose(out[0], want, atol=1e-5)


# ------------------------------------------------------- grouped expert ffn
@given(
    e=st.sampled_from([1, 2, 4, 8]),
    b=st.sampled_from([1, 4, 8]),
    h=st.sampled_from([16, 64]),
    f=st.sampled_from([32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_expert_ffn_grouped_matches_per_expert(e, b, h, f, seed):
    """The grouped (one-launch) kernel equals E independent expert kernels."""
    rng = np.random.default_rng(seed)
    x = rnd(rng, e, b, h, scale=0.5)
    w1 = rnd(rng, e, h, f, scale=h**-0.5)
    w3 = rnd(rng, e, h, f, scale=h**-0.5)
    w2 = rnd(rng, e, f, h, scale=f**-0.5)
    grouped = np.asarray(
        expert_k.expert_ffn_grouped(jnp.asarray(x), jnp.asarray(w1), jnp.asarray(w3), jnp.asarray(w2))
    )
    for i in range(e):
        single = np.asarray(
            expert_k.expert_ffn(jnp.asarray(x[i]), jnp.asarray(w1[i]), jnp.asarray(w3[i]), jnp.asarray(w2[i]))
        )
        np.testing.assert_allclose(grouped[i], single, atol=2e-4, rtol=2e-4)


@pytest.mark.parametrize("block_e", [1, 2, 4])
def test_expert_ffn_grouped_blocking_invariant(block_e):
    """Different expert-axis tilings must give identical results."""
    rng = np.random.default_rng(5)
    e, b, h, f = 4, 4, 16, 32
    args = [
        jnp.asarray(rnd(rng, e, b, h)),
        jnp.asarray(rnd(rng, e, h, f)),
        jnp.asarray(rnd(rng, e, h, f)),
        jnp.asarray(rnd(rng, e, f, h)),
    ]
    full = expert_k.expert_ffn_grouped(*args, block_e=e)
    tiled = expert_k.expert_ffn_grouped(*args, block_e=block_e)
    np.testing.assert_allclose(np.asarray(full), np.asarray(tiled), atol=1e-5)
