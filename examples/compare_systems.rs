//! The Figure-8 comparison as a driver: disaggregated MegaScale-Infer vs
//! vLLM-/TRT-LLM-style colocated fleets on one shared workload through the
//! same event-driven cluster engine.
//!
//! ```bash
//! cargo run --release --example compare_systems
//! ```
//!
//! Equivalent CLI: `msi compare --model mixtral --attention-gpu ampere`.

use megascale_infer::baselines::{run_compare, CompareConfig};
use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::workload::WorkloadSpec;

fn main() {
    let cfg = CompareConfig {
        // Fixed-length closed-loop workload: the deterministic steady-state
        // setting the golden test pins (tests/compare.rs).
        spec: WorkloadSpec {
            median_input: 256.0,
            median_output: 24.0,
            sigma: 0.0,
            ..Default::default()
        },
        seed: 7,
        ..CompareConfig::new(
            ModelConfig::mixtral_8x22b(),
            ClusterSpec::homogeneous(GpuKind::Ampere80G),
        )
    };
    let report = run_compare(&cfg).expect("comparison runs");
    println!("{}", report.summary());

    // The acceptance bar the repo holds itself to (paper Fig. 8 band).
    let ratio = report.ratio_vs_vllm();
    assert!(
        ratio >= 1.2,
        "disaggregated per-GPU throughput should beat vLLM-style by ≥1.2x, got {ratio:.2}x"
    );
    println!("\nacceptance: {ratio:.2}x ≥ 1.2x vs vLLM-style — OK");
}
