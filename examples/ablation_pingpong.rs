//! Ablation: ping-pong pipeline parallelism (paper §7.4, Figure 12) plus
//! the expert load-balancer ablation (§6) under skewed expert popularity.
//!
//! ```bash
//! cargo run --release --example ablation_pingpong
//! ```

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::{balance_experts, PingPongSim};
use megascale_infer::perf_model::PerfModel;
use megascale_infer::plan::PlanSearcher;
use megascale_infer::sim::SimRng;

fn main() {
    // --- micro-batch ablation (Figure 12) ---
    println!("== ping-pong ablation: throughput vs m (DBRX, const micro-batch) ==");
    let model = ModelConfig::dbrx();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    // Use the *balanced* optimal plan's operating point (§7.4).
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), 730.0)
        .search()
        .expect("plan");
    let pm = PerfModel::new(&model, &cluster, plan.tp_a, plan.tp_e, 730.0);
    let (b_a, n_a) = (plan.b_a(), plan.n_a as f64);
    let b_e = plan.b_e(&model);
    let (t_a, t_e, t_c) = (pm.t_a(b_a), pm.t_e(b_e), pm.t_c(b_a, b_e));
    println!(
        "per-layer: T_a {:.0}us  T_e {:.0}us  T_c {:.0}us  (min m = {:.0})",
        t_a * 1e6,
        t_e * 1e6,
        t_c * 1e6,
        (2.0 * (1.0 + t_c / t_a.max(t_e))).ceil()
    );
    let mut prev = None;
    for m in 1..=5 {
        let s = PingPongSim {
            t_a,
            t_e,
            t_c,
            m,
            layers: model.layers,
        }
        .run();
        let tput = m as f64 * b_a * n_a / s.total_time;
        let gain = prev.map(|p: f64| tput / p).unwrap_or(1.0);
        println!(
            "m={m}: {:>8.0} tok/s  (x{:.2} vs m={})  attn busy {:>3.0}%  expert busy {:>3.0}%",
            tput,
            gain,
            m.max(2) - 1,
            s.attn_utilization * 100.0,
            s.expert_utilization * 100.0
        );
        prev = Some(tput);
    }

    // --- load-balance ablation (§6) ---
    println!("\n== expert load balance: static placement vs greedy redundancy ==");
    let mut rng = SimRng::new(3);
    let experts = 16;
    let mut traffic = vec![0.0f64; experts];
    for _ in 0..200_000 {
        let e = ((rng.uniform().powf(2.5)) * experts as f64) as usize;
        traffic[e.min(experts - 1)] += 1.0;
    }
    let nodes = 16;
    let static_makespan = traffic
        .iter()
        .map(|&t| t.max(1000.0))
        .fold(0.0f64, f64::max);
    let balanced = balance_experts(&traffic, nodes, 1000.0);
    println!("traffic (tokens per expert): {traffic:.0?}");
    println!(
        "static one-expert-per-node makespan: {:.0}   greedy-redundancy makespan: {:.0}  ({:.2}x better)",
        static_makespan,
        balanced.makespan,
        static_makespan / balanced.makespan
    );
    let replicated: Vec<usize> = (0..experts)
        .filter(|&i| balanced.replicas(i) > 1)
        .collect();
    println!("experts replicated across nodes: {replicated:?}");
}
