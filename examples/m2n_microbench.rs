//! M2N communication microbenchmark — compare the MegaScale RDMA-style
//! library, NCCL, and the perftest floor on the token-dispatch pattern,
//! including bidirectional ping-pong traffic.
//!
//! ```bash
//! cargo run --release --example m2n_microbench
//! ```

use megascale_infer::m2n::{simulate_m2n, LibraryKind, LibraryProfile, M2nScenario};

fn main() {
    println!("== M2N microbenchmark: 8 senders -> 8 receivers, 256 KB ==");
    println!(
        "{:<10} {:>10} {:>10} {:>10} {:>12}",
        "library", "p50 (us)", "p99 (us)", "max (us)", "GB/s per GPU"
    );
    for kind in [
        LibraryKind::Perftest,
        LibraryKind::MegaScale,
        LibraryKind::Nccl,
    ] {
        let s = simulate_m2n(&M2nScenario {
            profile: LibraryProfile::of(kind),
            senders: 8,
            receivers: 8,
            msg_bytes: 256 * 1024,
            rounds: 2000,
            bidirectional: false,
            seed: 42,
        });
        println!(
            "{:<10} {:>10.1} {:>10.1} {:>10.1} {:>12.2}",
            format!("{kind:?}"),
            s.latency.median() * 1e6,
            s.latency.p99() * 1e6,
            s.latency.max() * 1e6,
            s.throughput / 1e9
        );
    }

    println!("\n== bidirectional (ping-pong pipeline in flight both ways) ==");
    for kind in [LibraryKind::MegaScale, LibraryKind::Nccl] {
        let s = simulate_m2n(&M2nScenario {
            profile: LibraryProfile::of(kind),
            senders: 8,
            receivers: 8,
            msg_bytes: 256 * 1024,
            rounds: 2000,
            bidirectional: true,
            seed: 42,
        });
        println!(
            "{:<10} p50 {:>8.1} us   p99 {:>8.1} us   ({})",
            format!("{kind:?}"),
            s.latency.median() * 1e6,
            s.latency.p99() * 1e6,
            if matches!(kind, LibraryKind::MegaScale) {
                "high-priority ACKs: no degradation"
            } else {
                "ACKs queued behind data: degraded"
            }
        );
    }
}
