//! Programmatic scenario-grid sweep: the library API behind `msi sweep`.
//!
//! Runs a small arrival-rate × popularity-skew × micro-batch × tenant-mix
//! grid through the streaming cluster engine on worker threads, prints the
//! per-cell scalars, and verifies the report is byte-identical when re-run
//! with the same base seed (the property CI relies on).
//!
//! ```bash
//! cargo run --release --example sweep_grid
//! ```

use megascale_infer::baselines::SystemKind;
use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::plan::PlanSearcher;
use megascale_infer::sim::sweep::{run_sweep, sweep_to_csv, sweep_to_json, SweepGrid};
use megascale_infer::workload::{TenantClass, WorkloadSpec};

fn main() {
    let model = ModelConfig::tiny();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
    let spec = WorkloadSpec::tiny_bench();
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len())
        .search()
        .expect("a feasible plan exists");

    let grid = SweepGrid {
        model,
        cluster,
        plan,
        spec,
        requests: 128,
        base_seed: 42,
        rates: vec![0.0, 200.0, 400.0],
        skews: vec![0.0, 1.2],
        micro_batches: vec![1, 2],
        // Prompt-length axis: the base spec's median vs a long-prompt mix
        // that loads the prefill pool (0 = keep the spec's median).
        prompt_lens: vec![0.0, 512.0],
        tenant_mixes: vec![
            Vec::new(),
            vec![
                TenantClass {
                    name: "interactive".into(),
                    weight: 0.7,
                    slo_e2e: 2.0,
                },
                TenantClass {
                    name: "batch".into(),
                    weight: 0.3,
                    slo_e2e: 60.0,
                },
            ],
        ],
        // The serving-system axis: disaggregated plus the vLLM-style
        // colocated fleet sized to the plan's GPU count (`msi compare` as
        // a grid dimension).
        systems: vec![SystemKind::Disaggregated, SystemKind::Vllm],
    };

    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1);
    let cells = run_sweep(&grid, workers);
    println!("{} cells on {} workers:", cells.len(), workers);
    for c in &cells {
        println!(
            "rate {:>6.1}  skew {:>4.2}  m {}  mix {}  {:<9} | {:>9.1} tok/s | \
             E2E p99 {:>7.3}s | rejected {} unserved {} | peak in-flight {}",
            c.rate,
            c.skew,
            c.m,
            c.tenant_mix,
            c.system,
            c.throughput,
            c.e2e_p99,
            c.rejected,
            c.unserved_queued,
            c.peak_in_flight
        );
    }

    // The property `msi sweep` inherits: same seed, same bytes.
    let replay = run_sweep(&grid, 1);
    assert_eq!(
        sweep_to_json(&grid, &cells).to_string(),
        sweep_to_json(&grid, &replay).to_string(),
        "sweep report must be byte-identical across runs"
    );
    assert_eq!(sweep_to_csv(&cells), sweep_to_csv(&replay));
    println!("\nreplay: byte-identical JSON/CSV report (deterministic grid)");
}
