//! End-to-end cluster-simulator driver (the acceptance scenario): replay a
//! 1000-request synthetic trace through the FULL virtual-time serving path
//! — router → attention pool (continuous batching + paged KV) → gating
//! top-k dispatch → M2N transfer → expert pool → ping-pong pipelining over
//! all layers — and report TTFT/TPOT percentiles and per-pool utilization.
//!
//! The run executes twice with the same seed and verifies the reports are
//! identical, demonstrating the simulator's bit-exact determinism.
//!
//! ```bash
//! cargo run --release --example serve_sim
//! ```

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::plan::PlanSearcher;
use megascale_infer::sim::cluster::{ClusterSim, ClusterSimConfig, ExpertPopularity};
use megascale_infer::workload::{RequestStream, TenantClass, Trace, WorkloadSpec};

fn main() {
    // 1. The model + hardware of the paper's homogeneous testbed.
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);

    // 2. A 1000-request synthetic trace: production length distributions
    //    (§7.1 medians) with bursty open-loop arrivals, split across two
    //    traffic classes with their own end-to-end SLOs.
    let tenants = vec![
        TenantClass {
            name: "interactive".into(),
            weight: 0.7,
            slo_e2e: 5.0,
        },
        TenantClass {
            name: "batch".into(),
            weight: 0.3,
            slo_e2e: 60.0,
        },
    ];
    let spec = WorkloadSpec {
        median_output: 64.0,
        arrival_rate: Some(400.0),
        burst_sigma: 0.6,
        tenants: tenants.clone(),
        ..Default::default()
    };
    let seed = 42;
    let trace = Trace::new(spec.generate(1000, seed));
    let stats = trace.stats();
    println!(
        "trace: {} requests | median input/output {}/{} tokens | ~{:.0} req/s",
        stats.count,
        stats.median_input,
        stats.median_output,
        400.0
    );

    // 3. Deployment plan via Algorithm 1.
    let plan = PlanSearcher::new(model.clone(), cluster.clone(), spec.avg_seq_len())
        .search()
        .expect("a feasible plan exists");
    println!(
        "plan: {} attention nodes x TP{} | {} expert nodes x TP{} | m={} | B={}",
        plan.n_a, plan.tp_a, plan.n_e, plan.tp_e, plan.m, plan.global_batch
    );

    // 4. Run the end-to-end event-driven cluster engine (skewed expert
    //    popularity — the realistic case — with the §6 balancer active and
    //    per-tenant SLO reporting).
    let cfg = ClusterSimConfig {
        popularity: ExpertPopularity::ZipfBalanced(1.0),
        seed,
        tenants,
        ..ClusterSimConfig::new(model, cluster, plan)
    };
    let report = ClusterSim::new(cfg.clone()).run(&trace.requests);
    println!("\n=== cluster simulation ===\n{}", report.summary());

    // 5. Determinism check: replay the SAME workload through the pull-based
    //    streaming generator (no preloaded trace — the engine only ever
    //    holds in-flight requests) and require a bit-identical report.
    let replay = ClusterSim::new(cfg)
        .run_streaming(Box::new(RequestStream::new(spec, 1000, seed)));
    assert_eq!(
        report.summary(),
        replay.summary(),
        "same-seed streaming replay diverged"
    );
    assert_eq!(report.elapsed.to_bits(), replay.elapsed.to_bits());
    println!(
        "\nstreaming replay with seed {seed}: identical report \
         (deterministic; peak in-flight {} of 1000 requests)",
        replay.peak_in_flight
    );
}
