//! Deployment plan search across all paper models and hardware options,
//! including the full heterogeneous pairing enumeration of §4.3.
//!
//! ```bash
//! cargo run --release --example plan_search
//! ```

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::plan::{search_heterogeneous, table3_kinds, PlanSearcher, SearchLimits};

fn main() {
    // Homogeneous plans per model on the Ampere testbed.
    println!("== homogeneous plans (Ampere-80GB, TPOT<=150ms, s=730) ==");
    for model in ModelConfig::paper_models() {
        let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);
        match PlanSearcher::new(model.clone(), cluster, 730.0).search() {
            Some(p) => println!(
                "{:<14} tp_a={} n_a={:<2} tp_e={} m={} B={:<5} TPOT {:>5.1}ms  {:>7.0} tok/s/GPU",
                model.name,
                p.tp_a,
                p.n_a,
                p.tp_e,
                p.m,
                p.global_batch,
                p.metrics.tpot * 1e3,
                p.metrics.per_gpu_throughput
            ),
            None => println!("{:<14} no feasible plan", model.name),
        }
    }

    // Every Table 3 pairing, ranked by throughput per dollar.
    println!("\n== heterogeneous pairings (Mixtral-8x22B, all Table 3 GPUs) ==");
    let results = search_heterogeneous(
        &ModelConfig::mixtral_8x22b(),
        &table3_kinds(),
        730.0,
        &SearchLimits::default(),
    );
    println!(
        "{:<22} {:>12} {:>10} {:>8}",
        "attention + experts", "tok/s/$", "tok/s", "GPUs"
    );
    for r in results.iter().take(10) {
        println!(
            "{:<22} {:>12.0} {:>10.0} {:>8}",
            format!("{:?} + {:?}", r.attention_gpu, r.expert_gpu),
            r.plan.metrics.throughput_per_dollar,
            r.plan.metrics.throughput,
            r.plan.total_gpus()
        );
    }
    if let Some(best) = results.first() {
        println!(
            "\nbest pairing: {:?} attention + {:?} experts (paper §4.3 expects H20 + L40S)",
            best.attention_gpu, best.expert_gpu
        );
    }
}
