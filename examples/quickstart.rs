//! Quickstart: plan a deployment for Mixtral-8x22B on an Ampere cluster,
//! inspect the plan, and simulate serving a synthetic workload on it.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use megascale_infer::config::{ClusterSpec, GpuKind, ModelConfig};
use megascale_infer::coordinator::RuntimeInstance;
use megascale_infer::plan::PlanSearcher;
use megascale_infer::workload::WorkloadSpec;

fn main() {
    // 1. Describe the model (paper Table 4) and the hardware.
    let model = ModelConfig::mixtral_8x22b();
    let cluster = ClusterSpec::homogeneous(GpuKind::Ampere80G);

    // 2. Describe the workload: the paper's production trace medians.
    let workload = WorkloadSpec::default(); // median in/out = 571/159 tokens

    // 3. Run the deployment plan search (paper Algorithm 1).
    let searcher = PlanSearcher::new(model.clone(), cluster.clone(), workload.avg_seq_len());
    let plan = searcher.search().expect("a feasible plan exists");
    println!("optimal deployment plan for {}:", model.name);
    println!(
        "  attention: {} nodes x TP{}   experts: {} nodes x TP{}   micro-batches: {}",
        plan.n_a, plan.tp_a, plan.n_e, plan.tp_e, plan.m
    );
    println!(
        "  prefill pool: {} nodes x {} GPUs (chunked prefill feeding the decode pools)",
        plan.n_p, plan.tp_p
    );
    println!(
        "  global batch {} | predicted TPOT {:.1} ms | {:.0} tok/s/GPU | {:.0} tok/s/$",
        plan.global_batch,
        plan.metrics.tpot * 1e3,
        plan.metrics.per_gpu_throughput,
        plan.metrics.throughput_per_dollar
    );
    println!(
        "  per-layer times: T_a {:.0} us, T_e {:.0} us, T_c {:.0} us (pipeline full: {})",
        plan.metrics.t_a * 1e6,
        plan.metrics.t_e * 1e6,
        plan.metrics.t_c * 1e6,
        plan.metrics.pipeline_full
    );

    // 4. Simulate decoding 256 requests on the planned instance
    //    (virtual-time discrete-event simulation of the full coordinator).
    let requests = workload.generate(256, 42);
    let report = RuntimeInstance::new(model, cluster, plan).simulate(&requests);
    println!("\nsimulated serving of {} requests:", report.completed);
    println!(
        "  {:.0} output tok/s ({:.0}/GPU) | TPOT p50 {:.1} ms p99 {:.1} ms",
        report.throughput,
        report.per_gpu_throughput,
        report.tpot.median() * 1e3,
        report.tpot.p99() * 1e3
    );
    println!(
        "  stage utilization: attention {:.0}%, experts {:.0}%",
        report.attn_utilization * 100.0,
        report.expert_utilization * 100.0
    );
}
