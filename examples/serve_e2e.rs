//! End-to-end serving driver (the required E2E validation): load the tiny
//! MoE compiled by the JAX/Pallas AOT path, and serve a batch of real
//! requests through the disaggregated decode loop on PJRT — attention
//! executable -> gating -> top-k dispatch -> per-expert executables ->
//! weighted combine -> sampling — reporting latency and throughput.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_e2e
//! ```

use std::path::Path;

use megascale_infer::runtime::ServingEngine;
use megascale_infer::workload::WorkloadSpec;

fn main() -> anyhow::Result<()> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(1);
    }

    // Two micro-batches shuttle between the attention and expert
    // executables in ping-pong order.
    let micro_batches = 2;
    let mut engine = ServingEngine::load(&dir, micro_batches)?;
    let md = engine.model().clone();
    println!(
        "loaded tiny MoE: {} layers, h={}, {} experts (top-{}), micro-batch {}, capacity {} slots",
        md.layers,
        md.hidden,
        md.experts,
        md.top_k,
        md.micro_batch,
        engine.capacity()
    );

    let spec = WorkloadSpec {
        median_input: 12.0,
        median_output: 16.0,
        sigma: 0.4,
        max_len: md.max_seq,
        ..Default::default()
    };
    let requests = spec.generate(24, 42);
    println!("serving {} requests (closed loop)...", requests.len());

    let report = engine.serve(&requests)?;
    println!(
        "\ncompleted {} requests, {} output tokens in {:.2}s",
        report.completed, report.output_tokens, report.elapsed
    );
    println!(
        "decode throughput: {:.1} tok/s over {} iterations",
        report.throughput, report.decode_iterations
    );
    println!(
        "TPOT: p50 {:.1} ms  p99 {:.1} ms  mean {:.1} ms",
        report.tpot.median() * 1e3,
        report.tpot.p99() * 1e3,
        report.tpot.mean() * 1e3
    );
    let total = report.attn_time + report.expert_time + report.coord_time;
    println!(
        "time split: attention(+gating) {:.1}%  experts {:.1}%  coordinator {:.1}%",
        report.attn_time / total * 100.0,
        report.expert_time / total * 100.0,
        report.coord_time / total * 100.0
    );
    Ok(())
}
