//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build environment has no access to a crates.io registry, so this
//! vendored shim provides the (small) surface the repository actually uses:
//!
//! * [`Error`] — an opaque, `Display`-able error value,
//! * [`Result<T>`] — `Result<T, Error>`,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * the [`anyhow!`], [`bail!`] and [`ensure!`] macros.
//!
//! Like the real crate, `Error` deliberately does **not** implement
//! `std::error::Error`: that is what makes the blanket
//! `impl<E: std::error::Error> From<E> for Error` coherent.

use std::fmt;

/// An opaque error: a rendered message chain.
///
/// The real `anyhow::Error` keeps the source chain alive; for this
/// repository's purposes (CLI + test diagnostics) the flattened
/// `"context: source"` rendering carries the same information.
pub struct Error {
    msg: String,
}

impl Error {
    /// Create an error from any displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self {
            msg: message.to_string(),
        }
    }

    /// Wrap an existing std error (mirrors `anyhow::Error::new`).
    pub fn new<E: std::error::Error + Send + Sync + 'static>(error: E) -> Self {
        Self {
            msg: render_chain(&error),
        }
    }

    /// Add a context line in front of this error (used by [`Context`]).
    pub fn context<C: fmt::Display>(self, context: C) -> Self {
        Self {
            msg: format!("{context}: {}", self.msg),
        }
    }
}

/// Render an error and its source chain as `"a: b: c"`.
fn render_chain(error: &(dyn std::error::Error)) -> String {
    let mut out = error.to_string();
    let mut src = error.source();
    while let Some(s) = src {
        out.push_str(": ");
        out.push_str(&s.to_string());
        src = s.source();
    }
    out
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `fn main() -> anyhow::Result<()>` prints the Debug form on exit;
        // show the human-readable message.
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Self {
        Error::new(error)
    }
}

/// `Result<T, anyhow::Error>`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors, on both `Result` and `Option`.
pub trait Context<T>: Sized {
    /// Wrap the error value with additional context.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error value with lazily-evaluated context.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(::std::format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(::std::format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an error if a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", ::std::stringify!($cond));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            $crate::bail!($($t)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening file").unwrap_err();
        assert_eq!(e.to_string(), "opening file: missing");

        let n: Option<u32> = None;
        let e = n.with_context(|| format!("key {}", 7)).unwrap_err();
        assert_eq!(e.to_string(), "key 7");
    }

    #[test]
    fn context_chains_on_anyhow_result() {
        fn inner() -> Result<()> {
            bail!("inner {}", 1);
        }
        let e = inner().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner 1");
    }

    #[test]
    fn ensure_and_bail() {
        fn check(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            ensure!(x != 5);
            Ok(x)
        }
        assert!(check(3).is_ok());
        assert_eq!(check(12).unwrap_err().to_string(), "x too big: 12");
        assert!(check(5).unwrap_err().to_string().contains("x != 5"));
    }
}
