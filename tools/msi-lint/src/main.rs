//! CLI entry point for `msi-lint`.
//!
//! Usage: `cargo run -p msi-lint -- rust/src [--json lint.json] [--waivers]`.
//! Exits 0 when every finding is waived, 1 when any active finding
//! remains, 2 on usage or I/O errors.

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "\
msi-lint — determinism & event-kernel invariant checker

usage: msi-lint [options] [path...]

  path          files or directories to lint (default: rust/src)
  --json FILE   write the full report as JSON (use `-` for stdout)
  --waivers     print the waiver inventory (per-rule counts + reasons)
  --list-rules  list the rule registry and exit
  -q, --quiet   suppress the per-finding listing, keep the summary
  -h, --help    this text

exit status: 0 clean, 1 active findings, 2 usage/io error";

fn main() -> ExitCode {
    let mut json_out: Option<String> = None;
    let mut show_waivers = false;
    let mut list_rules = false;
    let mut quiet = false;
    let mut paths: Vec<PathBuf> = Vec::new();
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        match a.as_str() {
            "--json" => match args.next() {
                Some(p) => json_out = Some(p),
                None => {
                    eprintln!("msi-lint: --json expects a file path\n{USAGE}");
                    return ExitCode::from(2);
                }
            },
            "--waivers" => show_waivers = true,
            "--list-rules" => list_rules = true,
            "-q" | "--quiet" => quiet = true,
            "-h" | "--help" => {
                println!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            other if other.starts_with('-') => {
                eprintln!("msi-lint: unknown option `{other}`\n{USAGE}");
                return ExitCode::from(2);
            }
            other => paths.push(PathBuf::from(other)),
        }
    }

    if list_rules {
        for r in msi_lint::RULES {
            println!("{:<28} {}", r.id, r.summary);
        }
        return ExitCode::SUCCESS;
    }

    if paths.is_empty() {
        paths.push(PathBuf::from("rust/src"));
    }

    let report = match msi_lint::lint_paths(&paths) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("msi-lint: {e}");
            return ExitCode::from(2);
        }
    };

    if !quiet {
        for f in report.active() {
            println!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message);
        }
    }

    if show_waivers {
        println!("waiver inventory:");
        for f in report.waived() {
            println!(
                "  {}:{}: [{}] waived -- {}",
                f.file,
                f.line,
                f.rule,
                f.waiver.as_deref().unwrap_or("")
            );
        }
        for (rule, _, waived) in report.rule_counts() {
            if waived > 0 {
                println!("  {rule}: {waived} waiver(s)");
            }
        }
    }

    if let Some(dest) = json_out {
        let doc = report.to_json();
        if dest == "-" {
            print!("{doc}");
        } else if let Err(e) = std::fs::write(&dest, doc) {
            eprintln!("msi-lint: writing {dest}: {e}");
            return ExitCode::from(2);
        }
    }

    let active = report.active().count();
    let waived = report.waived().count();
    if active > 0 {
        eprintln!(
            "msi-lint: {active} active finding(s), {waived} waived, {} file(s)",
            report.files
        );
        ExitCode::FAILURE
    } else {
        println!(
            "msi-lint: clean — {} file(s), {waived} waived finding(s)",
            report.files
        );
        ExitCode::SUCCESS
    }
}
