//! Hand-rolled Rust token scanner.
//!
//! The linter's rules are token-pattern matches, so the one hard
//! requirement on the lexer is that rule-pattern text inside string
//! literals, raw strings, char literals and comments must NEVER surface as
//! code tokens. String/char contents are dropped outright; comments are
//! kept as single tokens (waivers and `hot` markers live in them) but are
//! excluded from every code-pattern scan.
//!
//! The scanner never fails: unterminated constructs close at end of file,
//! because a linter must keep scanning whatever it is fed.

/// Kind of a lexed token.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword.
    Ident,
    /// Punctuation or operator (maximal munch for two-char operators, so
    /// `==` and `!=` are single tokens).
    Punct,
    /// Numeric literal.
    Num,
    /// String literal — regular, raw, byte or raw-byte. Contents dropped.
    Str,
    /// Character literal. Contents dropped.
    Char,
    /// Lifetime such as `'a`.
    Lifetime,
    /// Line or block comment, full text retained (directives are parsed
    /// out of comments).
    Comment,
}

/// One lexed token: kind, text and 1-based line of its first character.
#[derive(Debug, Clone)]
pub struct Tok {
    /// Token kind.
    pub kind: TokKind,
    /// Source text. Empty for string/char literals (contents must never
    /// match rule patterns); full text, delimiters included, for comments.
    pub text: String,
    /// 1-based line of the token's first character.
    pub line: u32,
}

/// Two-character operators lexed as single punctuation tokens. The rules
/// match `==`/`!=` as whole tokens, so maximal munch matters here.
const TWO_CHAR_OPS: [&str; 16] = [
    "==", "!=", "<=", ">=", "::", "->", "=>", "&&", "||", "+=", "-=", "*=", "/=", "..", "<<", ">>",
];

/// Lex `src` into tokens.
pub fn lex(src: &str) -> Vec<Tok> {
    let b: Vec<char> = src.chars().collect();
    let n = b.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = b[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        // Line comment.
        if c == '/' && i + 1 < n && b[i + 1] == '/' {
            let start = i;
            while i < n && b[i] != '\n' {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Comment,
                text: b[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Block comment (Rust block comments nest).
        if c == '/' && i + 1 < n && b[i + 1] == '*' {
            let start = i;
            let start_line = line;
            let mut depth = 1u32;
            i += 2;
            while i < n && depth > 0 {
                if b[i] == '\n' {
                    line += 1;
                    i += 1;
                } else if b[i] == '/' && i + 1 < n && b[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if b[i] == '*' && i + 1 < n && b[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    i += 1;
                }
            }
            toks.push(Tok {
                kind: TokKind::Comment,
                text: b[start..i].iter().collect(),
                line: start_line,
            });
            continue;
        }
        // Raw and byte strings: r"..", r#".."#, b"..", br#".."#.
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            if c == 'b' && j < n && b[j] == 'r' {
                j += 1;
            }
            let raw = c == 'r' || (j > i + 1);
            let mut hashes = 0usize;
            while raw && j < n && b[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && b[j] == '"' {
                let start_line = line;
                j += 1;
                if raw {
                    while j < n {
                        if b[j] == '\n' {
                            line += 1;
                            j += 1;
                        } else if b[j] == '"' && closes_raw(&b, j, hashes) {
                            j += 1 + hashes;
                            break;
                        } else {
                            j += 1;
                        }
                    }
                } else {
                    j = scan_cooked_string(&b, j, &mut line);
                }
                toks.push(Tok {
                    kind: TokKind::Str,
                    text: String::new(),
                    line: start_line,
                });
                i = j;
                continue;
            }
            // Not a string: fall through to identifier lexing below.
        }
        // Regular string literal.
        if c == '"' {
            let start_line = line;
            i = scan_cooked_string(&b, i + 1, &mut line);
            toks.push(Tok {
                kind: TokKind::Str,
                text: String::new(),
                line: start_line,
            });
            continue;
        }
        // Char literal or lifetime.
        if c == '\'' {
            if i + 1 < n && (b[i + 1].is_alphanumeric() || b[i + 1] == '_') {
                let mut j = i + 1;
                while j < n && (b[j].is_alphanumeric() || b[j] == '_') {
                    j += 1;
                }
                if j < n && b[j] == '\'' {
                    // 'a' — a char literal.
                    toks.push(Tok {
                        kind: TokKind::Char,
                        text: String::new(),
                        line,
                    });
                    i = j + 1;
                } else {
                    // 'a not followed by a closing quote — a lifetime.
                    toks.push(Tok {
                        kind: TokKind::Lifetime,
                        text: b[i..j].iter().collect(),
                        line,
                    });
                    i = j;
                }
                continue;
            }
            // Escaped or punctuation char literal: scan to the closing
            // quote (handles '\n', '\u{..}', '(' and friends).
            let start_line = line;
            let mut j = i + 1;
            if j < n && b[j] == '\\' {
                j += 2;
            }
            while j < n && b[j] != '\'' {
                if b[j] == '\n' {
                    line += 1;
                }
                j += 1;
            }
            toks.push(Tok {
                kind: TokKind::Char,
                text: String::new(),
                line: start_line,
            });
            i = (j + 1).min(n);
            continue;
        }
        // Numeric literal (digits, suffixes, and interior dots as in 1.5;
        // `0..n` stays three tokens because the dot needs a trailing digit).
        if c.is_ascii_digit() {
            let start = i;
            i += 1;
            while i < n
                && (b[i].is_alphanumeric()
                    || b[i] == '_'
                    || (b[i] == '.' && i + 1 < n && b[i + 1].is_ascii_digit()))
            {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Num,
                text: b[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Identifier or keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < n && (b[i].is_alphanumeric() || b[i] == '_') {
                i += 1;
            }
            toks.push(Tok {
                kind: TokKind::Ident,
                text: b[start..i].iter().collect(),
                line,
            });
            continue;
        }
        // Punctuation, two-char operators first.
        if i + 1 < n {
            let two: String = [b[i], b[i + 1]].iter().collect();
            if TWO_CHAR_OPS.contains(&two.as_str()) {
                toks.push(Tok {
                    kind: TokKind::Punct,
                    text: two,
                    line,
                });
                i += 2;
                continue;
            }
        }
        toks.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    toks
}

/// Whether the quote at `j` is followed by `hashes` `#` characters — the
/// terminator of a raw string opened with that many hashes.
fn closes_raw(b: &[char], j: usize, hashes: usize) -> bool {
    let mut k = 0usize;
    while k < hashes {
        if j + 1 + k >= b.len() || b[j + 1 + k] != '#' {
            return false;
        }
        k += 1;
    }
    true
}

/// Scan a cooked (escape-processing) string body starting just past the
/// opening quote; returns the index just past the closing quote and keeps
/// the line counter honest across multi-line strings.
fn scan_cooked_string(b: &[char], mut j: usize, line: &mut u32) -> usize {
    let n = b.len();
    while j < n {
        match b[j] {
            '\\' => {
                if j + 1 < n && b[j + 1] == '\n' {
                    *line += 1;
                }
                j += 2;
            }
            '\n' => {
                *line += 1;
                j += 1;
            }
            '"' => {
                j += 1;
                break;
            }
            _ => {
                j += 1;
            }
        }
    }
    j.min(n)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<(TokKind, String)> {
        lex(src).into_iter().map(|t| (t.kind, t.text)).collect()
    }

    #[test]
    fn idents_ops_and_numbers() {
        let t = kinds("let x = a.total_cmp(&b) != c;");
        assert!(t.contains(&(TokKind::Ident, "total_cmp".to_string())));
        assert!(t.contains(&(TokKind::Punct, "!=".to_string())));
        assert!(!t.iter().any(|(_, s)| s == "!"), "maximal munch on !=");
    }

    #[test]
    fn string_contents_never_become_code_tokens() {
        let t = kinds("let s = \"HashMap Instant::now() .unwrap()\";");
        assert!(!t.iter().any(|(_, s)| s == "HashMap" || s == "unwrap"));
        assert_eq!(t.iter().filter(|(k, _)| *k == TokKind::Str).count(), 1);
    }

    #[test]
    fn raw_string_with_hashes_and_embedded_quote() {
        let t = kinds("let s = r#\"schedule_at \" SystemTime\"#; let z = 1;");
        assert!(!t.iter().any(|(_, s)| s == "schedule_at" || s == "SystemTime"));
        assert!(
            t.contains(&(TokKind::Ident, "z".to_string())),
            "lexing resumes after the raw string"
        );
    }

    #[test]
    fn byte_strings_and_prefixed_idents() {
        let t = kinds("let a = b\"partial_cmp\"; let broken = rate;");
        assert!(!t.iter().any(|(_, s)| s == "partial_cmp"));
        assert!(t.contains(&(TokKind::Ident, "broken".to_string())));
        assert!(t.contains(&(TokKind::Ident, "rate".to_string())));
    }

    #[test]
    fn comments_are_single_tokens_with_text() {
        let t = lex("x; // msi-lint: hot\n/* HashMap\nin block */ y;");
        let comments: Vec<&Tok> = t.iter().filter(|t| t.kind == TokKind::Comment).collect();
        assert_eq!(comments.len(), 2);
        assert!(comments[0].text.contains("msi-lint: hot"));
        assert_eq!(comments[1].line, 2);
        let y = t.iter().find(|t| t.text == "y").expect("y survives");
        assert_eq!(y.line, 3, "line count tracks through block comments");
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let t = kinds("let c = 'a'; fn f<'a>(x: &'a str) {} let n = '\\n'; let p = '(';");
        assert_eq!(t.iter().filter(|(k, _)| *k == TokKind::Char).count(), 3);
        assert!(t.contains(&(TokKind::Lifetime, "'a".to_string())));
    }

    #[test]
    fn lines_are_one_based_and_accurate() {
        let t = lex("a\nb\n\nc");
        let lines: Vec<u32> = t.iter().map(|t| t.line).collect();
        assert_eq!(lines, vec![1, 2, 4]);
    }
}
