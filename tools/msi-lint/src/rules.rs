//! The rule registry and the token-stream analyses the rules share.
//!
//! Every rule is a token-pattern match scoped by a light structural pass:
//! brace-matched `#[cfg(test)]` spans, function spans (with `hot` markers
//! attached), and `impl Component for ...` spans. That is deliberately far
//! short of a parser — the invariants being enforced are textual
//! conventions, and a scanner that cannot be confused by macro soup is
//! worth more here than AST fidelity.

use crate::lexer::{Tok, TokKind};

/// A single diagnostic produced by a rule.
#[derive(Debug, Clone)]
pub struct Finding {
    /// Rule identifier (one of [`RULES`]).
    pub rule: &'static str,
    /// File the finding is in, as the path was passed to the linter.
    pub file: String,
    /// 1-based source line.
    pub line: u32,
    /// Human-readable explanation.
    pub message: String,
    /// Waiver reason when an inline `msi-lint: allow(...)` covers this
    /// finding; `None` means the finding is active and fails the lint.
    pub waiver: Option<String>,
}

/// Descriptor of one lint rule.
#[derive(Debug, Clone, Copy)]
pub struct RuleInfo {
    /// Stable identifier used in diagnostics and waivers.
    pub id: &'static str,
    /// One-line summary of the invariant the rule enforces.
    pub summary: &'static str,
}

/// Rule id of the linter's own meta-rule: malformed or unused waivers.
/// It cannot itself be waived.
pub const WAIVER_RULE: &str = "lint-waiver";

/// The registry: every determinism / event-kernel invariant the linter
/// enforces, plus the unwaivable meta-rule for broken waivers.
pub const RULES: [RuleInfo; 7] = [
    RuleInfo {
        id: "nondeterministic-iteration",
        summary: "HashMap/HashSet in report-affecting modules; use BTreeMap or sorted keys",
    },
    RuleInfo {
        id: "wall-clock-in-sim",
        summary: "Instant/SystemTime in simulation code; virtual time only",
    },
    RuleInfo {
        id: "raw-schedule",
        summary: "schedule_at outside sim/mod.rs; use try_schedule_at (epsilon discipline)",
    },
    RuleInfo {
        id: "float-time-compare",
        summary: "==/!=/partial_cmp on virtual-time values; use total_cmp",
    },
    RuleInfo {
        id: "hot-path-alloc",
        summary: "allocating call inside a `// msi-lint: hot` function",
    },
    RuleInfo {
        id: "unwrap-in-engine",
        summary: ".unwrap()/.expect() in event-kernel files or Component::handle paths",
    },
    RuleInfo {
        id: WAIVER_RULE,
        summary: "malformed or unused msi-lint waiver (not waivable)",
    },
];

/// Inclusive raw-token index range.
#[derive(Debug, Clone, Copy)]
struct Span {
    start: usize,
    end: usize,
}

impl Span {
    fn contains(&self, idx: usize) -> bool {
        self.start <= idx && idx <= self.end
    }
}

/// A function body span with its name and `hot` marking.
#[derive(Debug, Clone)]
struct FnSpan {
    name: String,
    span: Span,
    hot: bool,
}

/// One parsed `// msi-lint: allow(rule, ...) -- reason` comment.
#[derive(Debug)]
struct Waiver {
    rules: Vec<String>,
    reason: String,
    /// Line whose findings this waiver covers.
    covers: u32,
    /// Line the waiver comment itself is on.
    at: u32,
    used: bool,
}

/// Modules whose contents feed `ClusterReport` or any other artifact that
/// must be byte-identical across reruns.
const REPORT_MODULES: [&str; 6] = [
    "sim/", "coordinator/", "plan/", "workload/", "metrics/", "baselines/",
];

/// The event-kernel files where rule 6 applies to every non-test panic
/// site, not just `Component::handle` bodies.
const ENGINE_FILES: [&str; 3] = ["sim/mod.rs", "sim/engine.rs", "sim/pipeline.rs"];

/// Whether `path` (with `/` separators) is report-affecting.
fn report_scope(path: &str) -> bool {
    REPORT_MODULES
        .iter()
        .any(|m| path.starts_with(m) || path.contains(&format!("/{m}")))
}

/// Whether `path` is one of the event-kernel files.
fn engine_file(path: &str) -> bool {
    ENGINE_FILES.iter().any(|f| path.ends_with(f))
}

/// Identifiers the float-time-compare rule treats as virtual-time values.
fn timeish(s: &str) -> bool {
    s == "now" || s == "time" || s.starts_with("t_") || s.ends_with("_time")
}

/// Container types whose `::new`/`::with_capacity`/`::from` allocate.
const ALLOC_CONTAINERS: [&str; 9] = [
    "Vec",
    "VecDeque",
    "String",
    "Box",
    "HashMap",
    "HashSet",
    "BTreeMap",
    "BTreeSet",
    "BinaryHeap",
];

/// Method calls that allocate when invoked on a container or iterator.
const ALLOC_METHODS: [&str; 5] = ["collect", "to_vec", "to_string", "to_owned", "clone"];

/// Find the raw index of the `}` matching the `{` at raw index `open`.
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth: i64 = 0;
    let mut i = open;
    while i < toks.len() {
        let t = &toks[i];
        if t.kind == TokKind::Punct {
            if t.text == "{" {
                depth += 1;
            } else if t.text == "}" {
                depth -= 1;
                if depth == 0 {
                    return i;
                }
            }
        }
        i += 1;
    }
    toks.len().saturating_sub(1)
}

/// Structural facts about one file's token stream.
struct Analysis {
    /// Raw indices of non-comment tokens, in order.
    code: Vec<usize>,
    test_spans: Vec<Span>,
    fn_spans: Vec<FnSpan>,
    component_spans: Vec<Span>,
}

impl Analysis {
    fn in_test(&self, idx: usize) -> bool {
        self.test_spans.iter().any(|s| s.contains(idx))
    }

    fn in_component(&self, idx: usize) -> bool {
        self.component_spans.iter().any(|s| s.contains(idx))
    }
}

/// Run the structural pass: code-token index, `#[cfg(test)]` spans,
/// function spans with hot markers, and `impl Component for` spans.
fn analyze(toks: &[Tok]) -> Analysis {
    let code: Vec<usize> = (0..toks.len())
        .filter(|&i| toks[i].kind != TokKind::Comment)
        .collect();
    let is = |k: usize, text: &str| -> bool {
        let t = &toks[code[k]];
        t.text == text
    };

    // #[cfg(test)] spans: the token run `# [ cfg ( test ) ]`, then the
    // next `{` opens the guarded item.
    let mut test_spans = Vec::new();
    let mut k = 0usize;
    while k + 6 < code.len() {
        if is(k, "#")
            && is(k + 1, "[")
            && is(k + 2, "cfg")
            && is(k + 3, "(")
            && is(k + 4, "test")
            && is(k + 5, ")")
            && is(k + 6, "]")
        {
            let mut m = k + 7;
            while m < code.len() && !is(m, "{") {
                m += 1;
            }
            if m < code.len() {
                let open = code[m];
                test_spans.push(Span {
                    start: open,
                    end: match_brace(toks, open),
                });
            }
        }
        k += 1;
    }

    // `impl Component for Foo { .. }` spans.
    let mut component_spans = Vec::new();
    let mut k = 0usize;
    while k + 2 < code.len() {
        if is(k, "impl") && is(k + 1, "Component") && is(k + 2, "for") {
            let mut m = k + 3;
            while m < code.len() && !is(m, "{") {
                m += 1;
            }
            if m < code.len() {
                let open = code[m];
                component_spans.push(Span {
                    start: open,
                    end: match_brace(toks, open),
                });
            }
        }
        k += 1;
    }

    // `// msi-lint: hot` markers. A marker applies to the next `fn`
    // keyword, provided only signature-prefix tokens (doc comments,
    // attributes, visibility) separate them — a `{`, `}` or `;` in
    // between means the marker dangles and is ignored.
    let mut hot_fns: Vec<usize> = Vec::new();
    for (m, t) in toks.iter().enumerate() {
        if t.kind == TokKind::Comment && t.text.contains("msi-lint: hot") {
            let mut j = m + 1;
            while j < toks.len() {
                let u = &toks[j];
                if u.kind == TokKind::Ident && u.text == "fn" {
                    hot_fns.push(j);
                    break;
                }
                if u.kind == TokKind::Punct && (u.text == "{" || u.text == "}" || u.text == ";") {
                    break;
                }
                j += 1;
            }
        }
    }

    // Function spans: `fn <name> .. { body }`. A trailing-semicolon form
    // (trait method declaration) has no body and is skipped.
    let mut fn_spans = Vec::new();
    let mut k = 0usize;
    while k < code.len() {
        if is(k, "fn") && toks[code[k]].kind == TokKind::Ident {
            let fn_raw = code[k];
            let name = if k + 1 < code.len() && toks[code[k + 1]].kind == TokKind::Ident {
                toks[code[k + 1]].text.clone()
            } else {
                String::from("<anonymous>")
            };
            let mut m = k + 1;
            while m < code.len() && !is(m, "{") && !is(m, ";") {
                m += 1;
            }
            if m < code.len() && is(m, "{") {
                let open = code[m];
                fn_spans.push(FnSpan {
                    name,
                    span: Span {
                        start: open,
                        end: match_brace(toks, open),
                    },
                    hot: hot_fns.contains(&fn_raw),
                });
            }
        }
        k += 1;
    }

    Analysis {
        code,
        test_spans,
        fn_spans,
        component_spans,
    }
}

/// Parse waiver directives out of the comment tokens. Malformed waivers
/// (unknown rule, missing reason, unparseable syntax) come back as
/// immediate `lint-waiver` findings.
fn parse_waivers(file: &str, toks: &[Tok]) -> (Vec<Waiver>, Vec<Finding>) {
    let mut waivers = Vec::new();
    let mut findings = Vec::new();
    for t in toks {
        if t.kind != TokKind::Comment {
            continue;
        }
        let Some(pos) = t.text.find("msi-lint:") else {
            continue;
        };
        let rest = t.text[pos + "msi-lint:".len()..].trim_start();
        if rest.starts_with("hot") {
            continue; // hot markers are handled by the structural pass
        }
        let mut malformed = |why: &str| {
            findings.push(Finding {
                rule: WAIVER_RULE,
                file: file.to_string(),
                line: t.line,
                message: format!("malformed waiver: {why}"),
                waiver: None,
            });
        };
        let Some(inner) = rest.strip_prefix("allow(") else {
            malformed("expected `allow(<rule>) -- <reason>` or `hot` after `msi-lint:`");
            continue;
        };
        let Some(close) = inner.find(')') else {
            malformed("missing `)` after rule list");
            continue;
        };
        let rule_list = &inner[..close];
        let mut rules = Vec::new();
        let mut bad_rule = false;
        for r in rule_list.split(',') {
            let r = r.trim();
            if r.is_empty() {
                continue;
            }
            let known = RULES.iter().any(|info| info.id == r);
            if !known || r == WAIVER_RULE {
                malformed(&format!("unknown or unwaivable rule `{r}`"));
                bad_rule = true;
                break;
            }
            rules.push(r.to_string());
        }
        if bad_rule {
            continue;
        }
        if rules.is_empty() {
            malformed("empty rule list");
            continue;
        }
        let after = inner[close + 1..].trim_start();
        let Some(reason) = after.strip_prefix("--") else {
            malformed("missing ` -- <reason>` (a reason is mandatory)");
            continue;
        };
        let reason = reason.trim().trim_end_matches("*/").trim();
        if reason.is_empty() {
            malformed("empty reason (a reason is mandatory)");
            continue;
        }
        // A trailing waiver covers its own line; a standalone-comment
        // waiver covers the first code line after it.
        let covers = if toks.iter().any(|u| u.kind != TokKind::Comment && u.line == t.line) {
            t.line
        } else {
            toks.iter()
                .filter(|u| u.kind != TokKind::Comment && u.line > t.line)
                .map(|u| u.line)
                .next()
                .unwrap_or(t.line + 1)
        };
        waivers.push(Waiver {
            rules,
            reason: reason.to_string(),
            covers,
            at: t.line,
            used: false,
        });
    }
    (waivers, findings)
}

/// Run every rule over one file's token stream and resolve waivers.
pub fn run_rules(file: &str, toks: &[Tok]) -> Vec<Finding> {
    let a = analyze(toks);
    let (mut waivers, mut broken) = parse_waivers(file, toks);
    let in_report = report_scope(file);
    let in_engine = engine_file(file);
    let queue_owner = file.ends_with("sim/mod.rs");

    // (rule, line, message) triples before waiver resolution.
    let mut raw: Vec<(&'static str, u32, String)> = Vec::new();
    let code = &a.code;

    for k in 0..code.len() {
        let idx = code[k];
        let t = &toks[idx];
        let prev1 = k.checked_sub(1).map(|j| &toks[code[j]]);
        let next1 = code.get(k + 1).map(|&i| &toks[i]);
        let next2 = code.get(k + 2).map(|&i| &toks[i]);
        let next3 = code.get(k + 3).map(|&i| &toks[i]);

        // Rule 1: unordered maps anywhere in report-affecting modules
        // (tests included — a test that iterates a HashMap to build an
        // expectation is itself order-dependent).
        if in_report && t.kind == TokKind::Ident && (t.text == "HashMap" || t.text == "HashSet") {
            raw.push((
                "nondeterministic-iteration",
                t.line,
                format!("`{}` in report module; use BTreeMap/BTreeSet or sorted keys", t.text),
            ));
        }

        // Rule 2: wall-clock time sources in simulation scope, tests
        // included (the two legitimate self-bench sites carry waivers).
        if in_report
            && t.kind == TokKind::Ident
            && (t.text == "Instant" || t.text == "SystemTime")
        {
            raw.push((
                "wall-clock-in-sim",
                t.line,
                format!("`{}` in simulation code; virtual time only", t.text),
            ));
        }

        // Rule 3: raw schedule calls outside the queue-owning module.
        // Test code is exempt (tests exercise the panic discipline).
        if !queue_owner
            && t.kind == TokKind::Ident
            && (t.text == "schedule_at" || t.text == "schedule_in")
            && !a.in_test(idx)
        {
            raw.push((
                "raw-schedule",
                t.line,
                format!("`{}` outside sim/mod.rs; route through try_schedule_at", t.text),
            ));
        }

        // Rule 4: float comparisons on virtual time outside tests.
        if in_report && !a.in_test(idx) {
            if t.kind == TokKind::Ident && t.text == "partial_cmp" {
                raw.push((
                    "float-time-compare",
                    t.line,
                    "`partial_cmp` on floats; use the total order `total_cmp`".to_string(),
                ));
            }
            if t.kind == TokKind::Punct && (t.text == "==" || t.text == "!=") {
                let prev_timeish =
                    prev1.is_some_and(|p| p.kind == TokKind::Ident && timeish(&p.text));
                // `x == self.now` / `x == sc.t_done`: look through one
                // receiver-dot pair on the right-hand side.
                let next_timeish = match (next1, next2, next3) {
                    (Some(n1), _, _) if n1.kind == TokKind::Ident && timeish(&n1.text) => true,
                    (Some(n1), Some(n2), Some(n3)) => {
                        n1.kind == TokKind::Ident
                            && n2.text == "."
                            && n3.kind == TokKind::Ident
                            && timeish(&n3.text)
                    }
                    _ => false,
                };
                if prev_timeish || next_timeish {
                    raw.push((
                        "float-time-compare",
                        t.line,
                        format!("`{}` compares virtual time exactly; use `total_cmp`", t.text),
                    ));
                }
            }
        }

        // Rule 6: panic sites in the event kernel. Applies to every
        // non-test site in the three kernel files, and to any
        // `impl Component for` block in any file.
        if t.kind == TokKind::Ident
            && (t.text == "unwrap" || t.text == "expect")
            && prev1.is_some_and(|p| p.text == ".")
            && next1.is_some_and(|n| n.text == "(")
            && !a.in_test(idx)
            && (in_engine || a.in_component(idx))
        {
            raw.push((
                "unwrap-in-engine",
                t.line,
                format!("`.{}()` in event-kernel code; handle it or waive with reason", t.text),
            ));
        }
    }

    // Rule 5: allocating calls inside `// msi-lint: hot` functions.
    for f in a.fn_spans.iter().filter(|f| f.hot) {
        for k in 0..code.len() {
            let idx = code[k];
            if !f.span.contains(idx) {
                continue;
            }
            let t = &toks[idx];
            if t.kind != TokKind::Ident {
                continue;
            }
            let prev = k.checked_sub(1).map(|j| &toks[code[j]]);
            let next1 = code.get(k + 1).map(|&i| &toks[i]);
            let next2 = code.get(k + 2).map(|&i| &toks[i]);
            let mut hit: Option<String> = None;
            if (t.text == "vec" || t.text == "format") && next1.is_some_and(|n| n.text == "!") {
                hit = Some(format!("`{}!` allocates", t.text));
            } else if ALLOC_CONTAINERS.contains(&t.text.as_str())
                && next1.is_some_and(|n| n.text == "::")
                && next2.is_some_and(|n| {
                    n.text == "new" || n.text == "with_capacity" || n.text == "from"
                })
            {
                hit = Some(format!(
                    "`{}::{}` allocates",
                    t.text,
                    next2.map_or("", |n| n.text.as_str())
                ));
            } else if ALLOC_METHODS.contains(&t.text.as_str())
                && prev.is_some_and(|p| p.text == ".")
                && next1.is_some_and(|n| n.text == "(")
            {
                hit = Some(format!("`.{}()` allocates", t.text));
            }
            if let Some(what) = hit {
                raw.push((
                    "hot-path-alloc",
                    t.line,
                    format!("{what} inside hot function `{}`", f.name),
                ));
            }
        }
    }

    // Resolve waivers: a finding on a covered line with a matching rule
    // is downgraded to waived.
    let mut findings: Vec<Finding> = Vec::new();
    for (rule, line, message) in raw {
        let mut waived: Option<String> = None;
        for w in waivers.iter_mut() {
            if w.covers == line && w.rules.iter().any(|r| r == rule) {
                w.used = true;
                waived = Some(w.reason.clone());
                break;
            }
        }
        findings.push(Finding {
            rule,
            file: file.to_string(),
            line,
            message,
            waiver: waived,
        });
    }

    // Unused waivers are findings too: a waiver that matches nothing is
    // either stale or mis-addressed, and both should be visible.
    for w in &waivers {
        if !w.used {
            findings.push(Finding {
                rule: WAIVER_RULE,
                file: file.to_string(),
                line: w.at,
                message: format!(
                    "unused waiver for [{}] (covers line {}); remove it or fix its placement",
                    w.rules.join(", "),
                    w.covers
                ),
                waiver: None,
            });
        }
    }

    findings.append(&mut broken);
    findings.sort_by(|x, y| x.line.cmp(&y.line).then_with(|| x.rule.cmp(y.rule)));
    findings
}
