//! `msi-lint` — determinism and event-kernel invariant checker for the
//! MegaScale-Infer reproduction.
//!
//! The simulator's correctness contract (byte-identical `ClusterReport`s
//! across fused/stepwise paths, shard counts and reruns) rests on textual
//! conventions: `total_cmp` ordering, no wall clock or unordered-map
//! iteration in report-affecting code, `try_schedule_at` discipline, an
//! allocation-free decode loop, and no panic shortcuts in the event
//! kernel. This crate turns those conventions into enforced rules with
//! file/line diagnostics, JSON output, and an inline waiver syntax:
//!
//! ```text
//! // msi-lint: allow(<rule>[, <rule>...]) -- <mandatory reason>
//! // msi-lint: hot            (marks the next fn as a hot decode path)
//! ```
//!
//! A trailing waiver covers its own line; a standalone-comment waiver
//! covers the next code line. Unused or malformed waivers are themselves
//! findings, so the exception inventory can only shrink by deletion.
//!
//! Dependency-free by design: the linter is part of the correctness
//! contract and must never be the thing that drags a dependency tree
//! into CI.

#![warn(missing_docs)]

pub mod lexer;
mod rules;

pub use rules::{Finding, RuleInfo, RULES, WAIVER_RULE};

use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// Result of linting a set of files.
#[derive(Debug, Default)]
pub struct LintReport {
    /// Number of files scanned.
    pub files: usize,
    /// Every finding, active and waived, in file-then-line order.
    pub findings: Vec<Finding>,
}

impl LintReport {
    /// Findings not covered by a waiver — these fail the lint.
    pub fn active(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.waiver.is_none())
    }

    /// Findings covered by an inline waiver.
    pub fn waived(&self) -> impl Iterator<Item = &Finding> {
        self.findings.iter().filter(|f| f.waiver.is_some())
    }

    /// Whether the lint passes (no active findings).
    pub fn is_clean(&self) -> bool {
        self.active().next().is_none()
    }

    /// `(rule, active, waived)` counts in registry order.
    pub fn rule_counts(&self) -> Vec<(&'static str, usize, usize)> {
        RULES
            .iter()
            .map(|r| {
                let active = self
                    .findings
                    .iter()
                    .filter(|f| f.rule == r.id && f.waiver.is_none())
                    .count();
                let waived = self
                    .findings
                    .iter()
                    .filter(|f| f.rule == r.id && f.waiver.is_some())
                    .count();
                (r.id, active, waived)
            })
            .collect()
    }

    /// Render the report as a JSON document (hand-rolled, no deps).
    pub fn to_json(&self) -> String {
        let mut s = String::with_capacity(4096);
        s.push_str("{\n");
        s.push_str(&format!("  \"files\": {},\n", self.files));
        s.push_str(&format!("  \"active\": {},\n", self.active().count()));
        s.push_str(&format!("  \"waived\": {},\n", self.waived().count()));
        s.push_str("  \"counts\": {\n");
        let counts = self.rule_counts();
        for (i, (rule, active, waived)) in counts.iter().enumerate() {
            s.push_str(&format!(
                "    \"{}\": {{\"active\": {}, \"waived\": {}}}{}\n",
                json_escape(rule),
                active,
                waived,
                if i + 1 < counts.len() { "," } else { "" }
            ));
        }
        s.push_str("  },\n");
        s.push_str("  \"findings\": [\n");
        for (i, f) in self.findings.iter().enumerate() {
            let waiver = match &f.waiver {
                Some(r) => format!("\"{}\"", json_escape(r)),
                None => "null".to_string(),
            };
            s.push_str(&format!(
                "    {{\"rule\": \"{}\", \"file\": \"{}\", \"line\": {}, \"message\": \"{}\", \"waiver\": {}}}{}\n",
                json_escape(f.rule),
                json_escape(&f.file),
                f.line,
                json_escape(&f.message),
                waiver,
                if i + 1 < self.findings.len() { "," } else { "" }
            ));
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Escape a string for embedding in a JSON document.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Lint one in-memory source file. `path` (with `/` separators) decides
/// rule scoping — e.g. anything under `sim/` is report-affecting.
pub fn lint_source(path: &str, src: &str) -> Vec<Finding> {
    let toks = lexer::lex(src);
    rules::run_rules(path, &toks)
}

/// Recursively collect `.rs` files under each path (a file argument is
/// taken as-is), sorted so diagnostics are deterministic.
pub fn collect_rs_files(paths: &[PathBuf]) -> io::Result<Vec<PathBuf>> {
    fn walk(dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
        let mut entries: Vec<PathBuf> = fs::read_dir(dir)?
            .map(|e| e.map(|e| e.path()))
            .collect::<io::Result<Vec<_>>>()?;
        entries.sort();
        for p in entries {
            if p.is_dir() {
                walk(&p, out)?;
            } else if p.extension().is_some_and(|e| e == "rs") {
                out.push(p);
            }
        }
        Ok(())
    }
    let mut out = Vec::new();
    for p in paths {
        if p.is_dir() {
            walk(p, &mut out)?;
        } else {
            out.push(p.clone());
        }
    }
    Ok(out)
}

/// Lint a set of files and/or directories.
pub fn lint_paths(paths: &[PathBuf]) -> io::Result<LintReport> {
    let files = collect_rs_files(paths)?;
    let mut rep = LintReport::default();
    for f in &files {
        let src = fs::read_to_string(f)?;
        let label = f.to_string_lossy().replace('\\', "/");
        rep.findings.extend(lint_source(&label, &src));
        rep.files += 1;
    }
    Ok(rep)
}
