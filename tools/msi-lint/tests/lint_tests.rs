//! Integration tests for msi-lint: a fixture corpus exercising every rule
//! (positive and negative, including lexer traps), waiver semantics, and a
//! self-run gate asserting the repository's own tree lints clean.

use msi_lint::{lint_paths, lint_source, Finding, LintReport};
use std::path::{Path, PathBuf};

/// Lint a fixture under its corpus-relative label so module scoping sees
/// the `sim/` (etc.) prefixes rather than the absolute checkout path.
fn fixture(rel: &str) -> Vec<Finding> {
    let path = Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures")
        .join(rel);
    let src = std::fs::read_to_string(&path).expect("fixture file exists");
    lint_source(rel, &src)
}

fn count_active(findings: &[Finding], rule: &str) -> usize {
    findings
        .iter()
        .filter(|f| f.rule == rule && f.waiver.is_none())
        .count()
}

#[test]
fn nondeterministic_iteration_fires_in_report_modules() {
    let f = fixture("sim/bad_iteration.rs");
    assert_eq!(count_active(&f, "nondeterministic-iteration"), 4, "{f:?}");
}

#[test]
fn wall_clock_fires_in_sim_code() {
    let f = fixture("sim/bad_wallclock.rs");
    assert_eq!(count_active(&f, "wall-clock-in-sim"), 2, "{f:?}");
}

#[test]
fn raw_schedule_fires_outside_queue_owner() {
    let f = fixture("sim/bad_schedule.rs");
    assert_eq!(count_active(&f, "raw-schedule"), 2, "{f:?}");
}

#[test]
fn float_time_compare_fires_on_eq_and_partial_cmp() {
    let f = fixture("sim/bad_time_cmp.rs");
    assert_eq!(count_active(&f, "float-time-compare"), 3, "{f:?}");
    // The `.unwrap()` on that partial_cmp is NOT an engine finding here:
    // the fixture is neither an engine file nor a Component impl.
    assert_eq!(count_active(&f, "unwrap-in-engine"), 0, "{f:?}");
}

#[test]
fn hot_path_alloc_fires_only_in_marked_functions() {
    let f = fixture("sim/bad_hot_alloc.rs");
    assert_eq!(count_active(&f, "hot-path-alloc"), 3, "{f:?}");
    // `cold()` calls to_vec() with no hot marker: silent.
    assert!(f.iter().all(|x| x.line < 14), "{f:?}");
}

#[test]
fn unwrap_fires_inside_component_impls() {
    let f = fixture("sim/bad_unwrap.rs");
    assert_eq!(count_active(&f, "unwrap-in-engine"), 1, "{f:?}");
}

#[test]
fn unwrap_fires_anywhere_in_engine_files() {
    let f = fixture("kernel/sim/engine.rs");
    assert_eq!(count_active(&f, "unwrap-in-engine"), 1, "{f:?}");
}

#[test]
fn pattern_text_in_literals_and_comments_is_silent() {
    let f = fixture("sim/good_clean.rs");
    assert!(f.is_empty(), "unexpected findings: {f:?}");
}

#[test]
fn schedule_calls_are_legal_in_the_queue_owner() {
    let f = fixture("sim/mod.rs");
    assert!(f.is_empty(), "unexpected findings: {f:?}");
}

#[test]
fn scoped_rules_stay_quiet_outside_report_modules() {
    let f = fixture("util/outside_scope.rs");
    assert!(f.is_empty(), "unexpected findings: {f:?}");
}

#[test]
fn cfg_test_spans_are_exempt_from_schedule_and_time_rules() {
    let f = fixture("sim/test_only.rs");
    assert!(f.is_empty(), "unexpected findings: {f:?}");
}

#[test]
fn waivers_downgrade_one_finding_per_rule() {
    let f = fixture("sim/waived.rs");
    let active: Vec<_> = f.iter().filter(|x| x.waiver.is_none()).collect();
    assert!(active.is_empty(), "everything should be waived: {active:?}");
    assert_eq!(f.len(), 6, "one waived finding per substantive rule: {f:?}");
    for x in &f {
        let reason = x.waiver.as_deref().expect("waived");
        assert!(reason.contains("fixture"), "reason recorded verbatim: {x:?}");
    }
}

#[test]
fn broken_waivers_are_findings_themselves() {
    let f = fixture("sim/bad_waiver.rs");
    assert_eq!(count_active(&f, "lint-waiver"), 3, "{f:?}");
    // The waiver missing its reason does not suppress anything, so the
    // schedule call it sat above stays active too.
    assert_eq!(count_active(&f, "raw-schedule"), 1, "{f:?}");
}

#[test]
fn fixture_corpus_fails_the_lint() {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let report = lint_paths(&[dir]).expect("fixtures readable");
    assert!(
        !report.is_clean(),
        "the committed corpus must keep the linter honest"
    );
    assert!(report.active().count() >= 10);
}

#[test]
fn json_report_counts_active_and_waived() {
    let findings = lint_source("sim/x.rs", "use std::collections::HashMap;\n");
    let report = LintReport { files: 1, findings };
    let doc = report.to_json();
    assert!(doc.contains("\"active\": 1"), "{doc}");
    assert!(doc.contains("nondeterministic-iteration"), "{doc}");
}

#[test]
fn repository_lints_clean() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../rust/src");
    let report = lint_paths(&[src]).expect("rust/src readable");
    let active: Vec<String> = report
        .active()
        .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
        .collect();
    assert!(
        active.is_empty(),
        "unwaived findings in the tree:\n{}",
        active.join("\n")
    );
}
