//! Negative fixture: HashMap/Instant are tolerated outside the
//! report-affecting module paths (this file sits under `util/`).
use std::collections::HashMap;

pub fn cache() -> HashMap<String, std::time::Instant> {
    let mut m = HashMap::new();
    m.insert("start".to_string(), std::time::Instant::now());
    m
}
