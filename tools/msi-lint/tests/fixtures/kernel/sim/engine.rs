//! Positive fixture: `unwrap-in-engine` must fire anywhere in a file whose
//! path ends in an engine file name (here `sim/engine.rs`), even outside a
//! `Component` impl.
pub fn drain(q: &mut Vec<u64>) -> u64 {
    q.pop().expect("queue is non-empty")
}
