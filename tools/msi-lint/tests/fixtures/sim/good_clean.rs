//! Negative fixture: rule-pattern text inside strings, raw strings,
//! comments and char literals must never fire; the code itself is clean.

use std::collections::BTreeMap;

// A line comment mentioning HashMap, Instant::now(), schedule_at and
// .unwrap() must not trip anything.
/* Nor a block comment: SystemTime, partial_cmp, vec![0; 8], format!("x")
   /* nested: HashSet::new() */ still fine after the inner close. */

pub fn describe(map: &BTreeMap<u64, f64>) -> String {
    let plain = "HashMap Instant SystemTime schedule_at .unwrap() partial_cmp";
    let raw = r#"q.schedule_at(0.0, "Instant::now()") != now"#;
    let rawh = r##"nested "# quote: HashSet vec![1] "##;
    let bytes = b"schedule_at SystemTime";
    let braw = br#"partial_cmp .expect("x")"#;
    let tricky = "escaped \" quote then Instant::now()";
    let quote_char = '"';
    let escaped_char = '\'';
    let lt: &'static str = "lifetime 'a is not a char literal";
    let mut s = String::new();
    s.push(quote_char);
    s.push(escaped_char);
    s.push_str(plain);
    s.push_str(raw);
    s.push_str(rawh);
    s.push_str(tricky);
    s.push_str(lt);
    let _ = (bytes, braw);
    let n = map.len();
    let mut best = f64::NEG_INFINITY;
    for (_, v) in map.iter() {
        if v.total_cmp(&best).is_gt() {
            best = *v;
        }
    }
    format!("{n} entries, max {best}, notes {s}")
}

// msi-lint: hot
pub fn hot_and_clean(acc: &mut [f64], x: f64) -> f64 {
    let mut sum = 0.0;
    for a in acc.iter_mut() {
        *a += x;
        sum += *a;
    }
    sum
}
