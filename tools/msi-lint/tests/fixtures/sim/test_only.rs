//! Negative fixture: `raw-schedule` and `float-time-compare` are exempt
//! inside `#[cfg(test)]` spans.
#[cfg(test)]
mod tests {
    use crate::sim::EventQueue;

    #[test]
    fn drives_the_queue_directly() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 9);
        let order = 1.0f64.partial_cmp(&2.0);
        assert!(order.is_some());
        let now = 1.0;
        let t_end = 1.0;
        assert!(now == t_end);
    }
}
