//! Negative fixture: schedule_at/schedule_in are legal inside the
//! queue-owning module (`sim/mod.rs`).
pub fn prime(q: &mut EventQueue<u8>) {
    q.schedule_at(0.0, 1);
    q.schedule_in(0.5, 2);
}
