//! Positive fixture: `float-time-compare` must fire on ==/!= against
//! time-ish identifiers and on partial_cmp in non-test code.
pub fn same_tick(now: f64, t_end: f64, xs: &mut [f64]) -> bool {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    now == t_end
}

pub fn not_yet(now: f64, wake_time: f64) -> bool {
    wake_time != now
}
