//! Positive fixture: `nondeterministic-iteration` must fire on HashMap and
//! HashSet mentions inside a report-affecting module path (`sim/...`).
use std::collections::{HashMap, HashSet};

pub fn tally(loads: &HashMap<u64, f64>, seen: &HashSet<u64>) -> f64 {
    let mut sum = 0.0;
    for (id, l) in loads.iter() {
        if seen.contains(id) {
            sum += l;
        }
    }
    sum
}
