//! Positive fixture: `raw-schedule` must fire on schedule_at/schedule_in
//! outside the queue-owning module.
use crate::sim::EventQueue;

pub fn drive(q: &mut EventQueue<u32>) {
    q.schedule_at(1.0, 7);
    q.schedule_in(0.5, 8);
}
