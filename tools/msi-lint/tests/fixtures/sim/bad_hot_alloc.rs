//! Positive fixture: `hot-path-alloc` must fire on allocating calls inside
//! a function marked `// msi-lint: hot`.

// msi-lint: hot
pub fn hop(xs: &[u64]) -> Vec<u64> {
    let mut out = Vec::new();
    let doubled: Vec<u64> = xs.iter().map(|x| x * 2).collect();
    out.extend(doubled);
    let label = format!("{} items", out.len());
    drop(label);
    out
}

pub fn cold(xs: &[u64]) -> Vec<u64> {
    // Unmarked function: the same calls are fine here.
    xs.to_vec()
}
