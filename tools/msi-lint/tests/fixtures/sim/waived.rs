//! Negative fixture: one waived finding per rule. Every waiver carries a
//! reason and covers its line, so nothing here is active.
use std::collections::HashMap; // msi-lint: allow(nondeterministic-iteration) -- fixture: documented exception

pub fn bench() -> f64 {
    // msi-lint: allow(wall-clock-in-sim) -- fixture: wall-time bench site
    let t0 = std::time::Instant::now();
    t0.elapsed().as_secs_f64()
}

pub fn drive(q: &mut EventQueue<u8>, now: f64, t_end: f64) -> bool {
    // msi-lint: allow(raw-schedule) -- fixture: audited schedule site
    q.schedule_at(1.0, 3);
    // msi-lint: allow(float-time-compare) -- fixture: exact tie intended
    now == t_end
}

// msi-lint: hot
pub fn hot_with_waiver(n: usize) -> Vec<u64> {
    // msi-lint: allow(hot-path-alloc) -- fixture: grow-once buffer
    Vec::with_capacity(n)
}

impl Component for Probe {
    fn handle(&mut self, _now: f64, ev: &Event, ctx: &mut SimCtx, _out: &mut Vec<(f64, Event)>) {
        // msi-lint: allow(unwrap-in-engine) -- fixture: invariant documented here
        let _stage = ctx.stage.as_ref().unwrap();
        let _ = ev;
    }
}
