//! Positive fixture: `wall-clock-in-sim` must fire on Instant/SystemTime
//! inside a report-affecting module path.
pub fn stamp() -> f64 {
    let t0 = std::time::Instant::now();
    let _epoch = std::time::SystemTime::now();
    t0.elapsed().as_secs_f64()
}
