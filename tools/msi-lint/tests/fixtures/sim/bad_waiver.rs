//! Positive fixture: the `lint-waiver` meta-rule — waivers missing a
//! reason, naming an unknown rule, or covering nothing are findings.

// msi-lint: allow(raw-schedule)
pub fn missing_reason(q: &mut EventQueue<u8>) {
    q.schedule_at(1.0, 2);
}

// msi-lint: allow(not-a-rule) -- the rule name is wrong
pub fn unknown_rule() {}

pub fn unused() {
    // msi-lint: allow(wall-clock-in-sim) -- nothing on the covered line matches
    let x = 1 + 1;
    let _ = x;
}
