//! Positive fixture: `unwrap-in-engine` must fire inside any
//! `impl Component for ...` block, whatever the file.
use crate::sim::{Component, Event, SimCtx};

pub struct Gate;

impl Component for Gate {
    fn handle(&mut self, now: f64, ev: &Event, ctx: &mut SimCtx, out: &mut Vec<(f64, Event)>) {
        let t = ctx.stage.as_ref().unwrap();
        out.push((now + t.dt, ev.clone()));
    }
}

pub fn outside_the_impl(x: Option<u32>) -> u32 {
    // Not an engine file and not a Component impl: unwrap is tolerated.
    x.unwrap_or(0)
}
